"""Crash-recoverable market service: hard-kill bit-parity + degraded serving.

The headline suite hard-kills (``os._exit``) a durable MarketService in a
subprocess at each instrumented point — mid-ingest (after a WAL append,
before the acknowledgment), post-drain/pre-settle, post-settle/pre-record
— resumes it from disk, finishes the workload, and asserts the final
prices, EpochStats history, and exported book state are *bit-identical*
to an uninterrupted reference run (with ``parity_check()`` passing on the
recovered book).  The client-side resume contract is the realistic one:
re-issue everything unacknowledged; duplicated records collapse
idempotently.

The rest covers the availability layer in-process: deadline-bounded
ticks, the ServiceHealth machine, last-good price serving through failed
ticks, bounded history rings, and the real psi / operator-aware
pct_settled telemetry.
"""
import dataclasses
import os
import pickle
import subprocess
import sys

import numpy as np
import pytest

from repro.core.auction import ClockConfig
from repro.core.faults import FaultModel
from repro.core.markets import fleet_economy
from repro.serve import ServiceConfig
from repro.serve.market import BidDelta, MarketService

SEEDS = [0, 3, 7]
POINTS = ["mid_ingest", "post_drain", "post_settle"]
# commit-path kill points (fire inside the tick's durable commit, after the
# epoch has advanced): mid-delta-write, between-durable-save-and-truncate,
# mid-compaction, post-compaction-pre-prune, at the start of the async
# background write, and during the async write's overlap with the next
# (mutating) tick
COMMIT_POINTS = [
    "mid_delta",
    "post_delta_pre_truncate",
    "mid_compaction",
    "post_compaction",
    "pre_delta_write",
    "async_overlap",
]

# One deterministic three-tick workload (churn + withdraw + fault dropout),
# killable at tick 1 via the service's crash-point hooks, resumable from the
# WAL + checkpoint, and runnable WAL-less as the uninterrupted reference.
_SCRIPT = """
import sys, os, time
sys.path.insert(0, "src")
import dataclasses, pickle
import numpy as np
from repro.core.markets import fleet_economy
from repro.core.faults import FaultModel
from repro.serve import ServiceConfig
from repro.serve.market import MarketService, BidDelta

mode, point, seed, d = sys.argv[1], sys.argv[2], int(sys.argv[3]), sys.argv[4]
TICKS, KILL_TICK = 3, 1
ASYNC_POINTS = {"pre_delta_write", "async_overlap"}
FULL_POINTS = {"mid_compaction", "post_compaction"}
COMMIT_POINTS = {
    "mid_delta", "post_delta_pre_truncate", "mid_compaction",
    "post_compaction", "pre_delta_write",
}

eco = fleet_economy(40, 3, seed=seed)
cfg = ServiceConfig()
if mode != "ref":
    cfg = cfg.replace(
        wal_path=os.path.join(d, "w.wal"),
        checkpoint_dir=os.path.join(d, "ck"),
        async_commit=point in ASYNC_POINTS,
        # full_every=1 turns every commit into a compaction, so the
        # compaction kill points fire on the killed tick's commit
        checkpoint_full_every=1 if point in FULL_POINTS else 8,
    )
svc = MarketService.from_economy(
    eco, config=cfg, faults=FaultModel(bid_dropout=0.2, seed=seed)
)

keys, idx, val, mask, pi = eco.export_bid_rows()
live = np.flatnonzero(mask.any(axis=1))

def batch(t):
    rng = np.random.default_rng(seed * 1000 + t)
    pick = rng.choice(live, size=8, replace=False)
    out = []
    for j, i in enumerate(pick):
        bundles = [(idx[i, b], val[i, b]) for b in np.flatnonzero(mask[i])]
        out.append(BidDelta(keys[i], bundles, pi[i][mask[i]] * (0.9 + 0.02 * j)))
    return out, keys[pick[0]]

if mode == "crash":
    if point == "mid_ingest":
        seen = {"n": 0}
        def boom():
            if svc.epoch == KILL_TICK:
                seen["n"] += 1
                if seen["n"] == 5:  # 5th append of tick 1's batch, pre-ack
                    os._exit(1)
        svc._test_hooks[point] = boom
    elif point == "async_overlap":
        # die inside the NEXT tick's drain (the book is already mutated),
        # once the background record covering the killed boundary has
        # become durable — the write raced a mutating tick and must have
        # captured the pre-tick snapshot
        rec = os.path.join(d, "ck", "delta_%08d" % (KILL_TICK + 1))
        def boom():
            if svc.epoch == KILL_TICK + 1:
                while not os.path.isdir(rec):
                    time.sleep(0.005)
                os._exit(1)
        svc._test_hooks["post_drain"] = boom
    elif point in COMMIT_POINTS:
        def boom():
            if svc.epoch == KILL_TICK + 1:  # the killed tick's commit
                os._exit(1)
        svc._test_hooks[point] = boom
    else:
        def boom():
            if svc.epoch == KILL_TICK:
                os._exit(1)
        svc._test_hooks[point] = boom

# the client retries every delta it never saw acknowledged; re-submission is
# idempotent (last-write-wins pending + same deterministic batch content), so
# a resumed run simply re-issues the whole current-tick batch
for t in range(svc.epoch, TICKS):
    ds, wkey = batch(t)
    for dd in ds:
        svc.submit(dd)
    svc.withdraw(wkey)
    svc.tick()
    if mode == "crash" and point == "pre_delta_write" and t == KILL_TICK:
        time.sleep(60)  # the background writer's kill hook fires any moment

svc.flush()
svc.book.parity_check()
arrays, meta = svc.book.export_state()
out = dict(
    prices=np.stack(svc.price_history),
    last_price_epoch=svc._last_price_epoch,
    epoch=svc.epoch,
    stats=[dataclasses.asdict(s) for s in svc.stats_history],
    book_arrays=dict(arrays),
    book_meta=meta,
)
with open(os.path.join(d, f"out_{mode}.pkl"), "wb") as f:
    pickle.dump(out, f)
"""


def _run(mode, point, seed, workdir):
    return subprocess.run(
        [sys.executable, "-c", _SCRIPT, mode, point, str(seed), str(workdir)],
        env={**os.environ, "PYTHONPATH": "src"},
        cwd=os.getcwd(),
        capture_output=True,
        text=True,
        timeout=300,
    )


@pytest.fixture(scope="module")
def reference(tmp_path_factory):
    """One uninterrupted reference run per seed (shared across kill points)."""
    outs = {}
    for seed in SEEDS:
        d = tmp_path_factory.mktemp(f"ref{seed}")
        r = _run("ref", "-", seed, d)
        assert r.returncode == 0, r.stderr
        with open(d / "out_ref.pkl", "rb") as f:
            outs[seed] = pickle.load(f)
    return outs


def _assert_bit_identical(got, ref):
    np.testing.assert_array_equal(got["prices"], ref["prices"])
    assert got["last_price_epoch"] == ref["last_price_epoch"]
    assert got["epoch"] == ref["epoch"]
    assert len(got["stats"]) == len(ref["stats"])
    for sa, sb in zip(got["stats"], ref["stats"]):
        assert sa.keys() == sb.keys()
        for k, va in sa.items():
            vb = sb[k]
            if isinstance(va, np.ndarray):
                assert np.array_equal(va, vb), k
            elif isinstance(va, float) and np.isnan(va):
                assert np.isnan(vb), k
            else:
                assert va == vb, (k, va, vb)
    assert got["book_meta"] == ref["book_meta"]
    assert got["book_arrays"].keys() == ref["book_arrays"].keys()
    for k, va in got["book_arrays"].items():
        assert np.array_equal(va, ref["book_arrays"][k]), f"book/{k}"


@pytest.mark.parametrize("seed", SEEDS)
@pytest.mark.parametrize("point", POINTS + COMMIT_POINTS)
def test_hard_kill_recovery_bit_identical(tmp_path, reference, point, seed):
    r = _run("crash", point, seed, tmp_path)
    assert r.returncode == 1, f"kill hook never fired: {r.stderr}"
    assert not (tmp_path / "out_crash.pkl").exists()
    r = _run("resume", point, seed, tmp_path)
    assert r.returncode == 0, r.stderr
    with open(tmp_path / "out_resume.pkl", "rb") as f:
        got = pickle.load(f)
    _assert_bit_identical(got, reference[seed])


def test_checkpoint_without_wal_resumes_committed_state(tmp_path):
    """Checkpoint-only durability: committed ticks survive, the un-journaled
    pending queue (documented) does not."""
    eco = fleet_economy(30, 3, seed=0)
    cfg = ServiceConfig(checkpoint_dir=str(tmp_path))
    svc = MarketService.from_economy(eco, config=cfg)
    s0 = svc.tick()
    del svc
    svc2 = MarketService.from_economy(eco, config=cfg)
    assert svc2.restored_step == 1 and svc2.epoch == 1
    assert svc2.pending == 0
    np.testing.assert_array_equal(svc2.poll_prices()[0], s0.prices)
    svc2.book.parity_check()


def test_stale_checkpoint_offset_survives_compaction(tmp_path):
    """A crash can strand a checkpoint whose WAL offset predates a later
    compaction; the generation counter must prevent offset aliasing."""
    cfg = ServiceConfig(
        wal_path=str(tmp_path / "w.wal"), checkpoint_dir=str(tmp_path / "ck")
    )
    eco = fleet_economy(30, 3, seed=0)
    svc = MarketService.from_economy(eco, config=cfg)
    keys, idx, val, mask, pi = eco.export_bid_rows()
    i = int(np.flatnonzero(mask.any(axis=1))[0])
    bundles = [(idx[i, b], val[i, b]) for b in np.flatnonzero(mask[i])]
    svc.submit(BidDelta(keys[i], bundles, pi[i][mask[i]] * 1.05))
    svc.tick()  # commit: checkpoint stores gen g, then compaction bumps to g+1
    gen = svc._wal.generation
    svc.submit(BidDelta(keys[i], bundles, pi[i][mask[i]] * 1.10))
    del svc

    svc2 = MarketService.from_economy(eco, config=cfg)
    # the checkpoint's offset points into the dead generation g-1; recovery
    # must detect the mismatch and replay the whole surviving log instead of
    # seeking past the (post-compaction, smaller) record
    assert svc2._restored_wal_generation == gen - 1
    assert svc2._wal.generation == gen
    assert svc2.replayed_records == 1 and svc2.pending == 1


def test_mismatched_shape_restore_rejected(tmp_path):
    eco = fleet_economy(30, 3, seed=0)
    cfg = ServiceConfig(checkpoint_dir=str(tmp_path))
    svc = MarketService.from_economy(eco, config=cfg)
    svc.tick()
    with pytest.raises(ValueError, match="reconstruct the same service"):
        MarketService(
            np.ones(2, np.float32), num_bundles=1, k_bound=1, config=cfg
        )


def test_checkpoint_pruning_keeps_newest(tmp_path):
    # full_every=1: every record is a full checkpoint, so keep=2 retains
    # exactly the newest two steps (delta-chain retention is covered by
    # test_incremental_checkpoint.py)
    eco = fleet_economy(30, 3, seed=0)
    svc = MarketService.from_economy(
        eco,
        config=ServiceConfig(
            checkpoint_dir=str(tmp_path),
            checkpoint_keep=2,
            checkpoint_full_every=1,
        ),
    )
    for _ in range(4):
        svc.tick()
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("ckpt_")
    )
    assert steps == [3, 4]


# -- deadline-bounded ticks + health machine ---------------------------------


_STARVED = ClockConfig(max_rounds=3)  # guaranteed non-convergence


def _svc(seed=0, **kw):
    eco = fleet_economy(30, 3, seed=seed)
    return MarketService.from_economy(eco, config=ServiceConfig(**kw))


def test_failed_tick_commits_nothing_and_serves_last_good(seed=0):
    svc = _svc(seed)
    good = svc.tick()
    assert good.converged and svc.health.state == "healthy"
    p_good, e_good = svc.poll_prices()

    # force failure: cold-start a round-starved clock (a warm start from the
    # settled curve would trivially converge with zero excess demand)
    svc.clock = _STARVED
    svc.max_escalations = 0
    svc.warm_start = False
    bad = svc.tick()
    assert not bad.converged
    assert bad.health == "degraded" and bad.tick_failures == 1
    assert bad.retry_backoff_s == svc.backoff_base_s
    # nothing published: the last-good curve (and its epoch) keeps serving
    p_now, e_now = svc.poll_prices()
    np.testing.assert_array_equal(p_now, p_good)
    assert e_now == e_good
    assert len(svc.price_history) == 1
    # but the tick itself is recorded (epoch advances, stats appended)
    assert svc.epoch == 2 and svc.stats_history[-1] is bad

    bad2 = svc.tick()
    assert bad2.tick_failures == 2
    assert bad2.retry_backoff_s == 2 * svc.backoff_base_s

    svc.clock = ClockConfig()
    rec = svc.tick()
    assert rec.converged and rec.health == "recovering"
    assert rec.retry_backoff_s == 0.0 and rec.tick_failures == 0
    assert svc.poll_prices()[1] == rec.epoch
    ok = svc.tick()
    assert ok.health == "healthy"
    assert svc.health.total_failures == 2 and svc.health.recoveries == 1


def test_backoff_capped():
    svc = _svc(clock=_STARVED, max_escalations=0, backoff_base_s=1.0,
               backoff_cap_s=4.0)
    for _ in range(5):
        s = svc.tick()
    assert s.retry_backoff_s == 4.0 and s.tick_failures == 5


def test_escalation_ladder_rescues_starved_clock():
    svc = _svc(clock=_STARVED, max_escalations=8)
    s = svc.tick()
    assert s.converged and s.clock_escalations > 0
    assert s.health == "healthy" and not s.degraded


def test_zero_deadline_cuts_ladder_and_flags_miss():
    svc = _svc(clock=_STARVED, max_escalations=8)
    s = svc.tick(deadline_s=0.0)
    assert s.clock_escalations == 0  # no time left for any escalation
    assert s.deadline_missed and s.degraded and not s.converged
    assert svc.health.state == "degraded"


def test_deadline_default_comes_from_service():
    svc = _svc(clock=_STARVED, max_escalations=8, tick_deadline_s=0.0)
    s = svc.tick()
    assert s.deadline_missed and s.clock_escalations == 0
    # per-call deadline overrides the service default
    s2 = svc.tick(deadline_s=60.0)
    assert not s2.deadline_missed and s2.converged


def test_converged_but_late_tick_still_commits():
    svc = _svc()
    svc.tick()
    p0 = svc.poll_prices()[0]
    # ample rounds, impossible deadline: the first attempt converges, the
    # deadline only matters for further escalations — the result commits
    s = svc.tick(deadline_s=0.0)
    assert s.converged and s.deadline_missed
    assert svc.poll_prices()[1] == s.epoch
    assert not np.array_equal(p0, np.empty(0))


def test_dry_run_never_touches_health():
    svc = _svc(clock=_STARVED, max_escalations=0)
    s = svc.preview()
    assert not s.converged
    assert svc.health.state == "healthy"
    assert svc.health.consecutive_failures == 0
    assert svc.epoch == 0 and not svc.stats_history


# -- bounded history rings ----------------------------------------------------


def test_max_history_ring_bounds_memory():
    svc = _svc(max_history=3)
    for _ in range(7):
        svc.tick()
    assert len(svc.price_history) == 3
    assert len(svc.stats_history) == 3
    assert svc.epoch == 7
    # the tail is the newest: poll still serves the last settled epoch
    assert svc.poll_prices()[1] == 6
    assert [s.epoch for s in svc.stats_history] == [4, 5, 6]


# -- real psi + operator-aware pct_settled ------------------------------------


def test_psi_measures_settled_share_of_offered_supply():
    # one pool with 10 units on offer, one buyer taking 4 at a high price:
    # psi = 4/10 on that pool, 0 on the never-offered pool
    svc = MarketService(
        np.array([1.0, 1.0], np.float32), 1, 1,
        config=ServiceConfig(rows_cap=4),
    )
    svc.book.upsert(
        "op-0", [(np.array([0], np.int32), np.array([-10.0], np.float32))],
        [-10.0],
    )
    svc._operator_keys.add("op-0")
    svc.submit(BidDelta(
        "buyer", [(np.array([0], np.int32), np.array([4.0], np.float32))],
        [100.0],
    ))
    s = svc.tick()
    assert s.converged
    np.testing.assert_allclose(s.psi, [0.4, 0.0])
    # 1 of 1 *agent* rows settled; the operator row is excluded either side
    assert s.pct_settled == 100.0


def test_pct_settled_excludes_operator_rows():
    svc = _svc(seed=3)
    s = svc.tick()
    n_ops = sum(1 for k in svc._operator_keys if k in svc.book)
    assert n_ops > 0
    agent_rows = svc.book.num_rows - n_ops
    assert 0.0 <= s.pct_settled <= 100.0
    # recompute from the full-row rate: settled ops would otherwise inflate it
    assert s.pct_settled <= 100.0 * svc.book.num_rows / max(agent_rows, 1)
    assert np.all(s.psi >= 0.0)
    assert np.any(s.psi > 0.0)
