"""Per-assigned-architecture smoke tests: reduced config of the same family,
one forward/train step + one decode step on CPU; asserts shapes + no NaNs.
The FULL configs are exercised only via the dry-run (no allocation here)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke
from repro.configs.shapes import SHAPES, applicable
from repro.models import get_api, make_batch
from repro.models.params import count_params, init_params
from repro.train.optimizer import AdamW
from repro.train.train_step import init_train_state, make_train_step


@pytest.fixture(scope="module")
def trained():
    return {}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_family_matches_full(arch):
    smoke, full = get_smoke(arch), get_config(arch)
    assert smoke.family == full.family
    assert (smoke.moe is None) == (full.moe is None)
    assert (smoke.mla is None) == (full.mla is None)
    assert smoke.qk_norm == full.qk_norm and smoke.qkv_bias == full.qkv_bias


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    api = get_api(cfg)
    params = init_params(jax.random.PRNGKey(0), api.decls(cfg), jnp.float32)
    batch = make_batch(cfg, 2, 16)
    # forward (prefill) shapes
    logits = jax.jit(lambda p, b: api.prefill(p, b, cfg))(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits)))
    # one full train step
    opt = AdamW(lr=1e-3)
    step = make_train_step(cfg, opt)
    state = init_train_state(cfg, opt, params)
    new_params, new_state, metrics = jax.jit(step)(params, state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(new_state["opt"]["step"]) == 1
    # params actually changed
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params
    )
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_smoke(arch)
    api = get_api(cfg)
    params = init_params(jax.random.PRNGKey(1), api.decls(cfg), jnp.float32)
    cache = api.init_cache(cfg, 2, 24)
    tok = jnp.ones((2, 1), jnp.int32)
    step = jax.jit(lambda p, c, t, i: api.decode_step(p, c, t, i, cfg))
    logits, cache = step(params, cache, tok, jnp.int32(0))
    logits2, cache = step(params, cache, tok, jnp.int32(1))
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))


def test_decode_matches_prefill_dense():
    """Token-by-token decode logits == full-sequence forward logits."""
    cfg = get_smoke("qwen3-1.7b")
    api = get_api(cfg)
    params = init_params(jax.random.PRNGKey(2), api.decls(cfg), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab_size)
    full = api.prefill(params, {"tokens": toks}, cfg)  # (2, 8, V)
    cache = api.init_cache(cfg, 2, 8)
    outs = []
    for i in range(8):
        logits, cache = api.decode_step(params, cache, toks[:, i : i + 1], jnp.int32(i), cfg)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=2e-4, atol=2e-4)


def test_decode_matches_prefill_rwkv():
    cfg = get_smoke("rwkv6-7b")
    api = get_api(cfg)
    params = init_params(jax.random.PRNGKey(2), api.decls(cfg), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 10), 0, cfg.vocab_size)
    full = api.prefill(params, {"tokens": toks}, cfg)
    cache = api.init_cache(cfg, 1, 10)
    outs = []
    for i in range(10):
        logits, cache = api.decode_step(params, cache, toks[:, i : i + 1], jnp.int32(i), cfg)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=5e-3, atol=5e-3)


def test_decode_matches_prefill_griffin():
    cfg = get_smoke("recurrentgemma-2b")
    api = get_api(cfg)
    params = init_params(jax.random.PRNGKey(4), api.decls(cfg), jnp.float32)
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, 10), 0, cfg.vocab_size)
    full = api.prefill(params, {"tokens": toks}, cfg)
    cache = api.init_cache(cfg, 1, 10)
    outs = []
    for i in range(10):
        logits, cache = api.decode_step(params, cache, toks[:, i : i + 1], jnp.int32(i), cfg)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), rtol=5e-3, atol=5e-3)


def test_full_config_param_counts():
    """Full configs hit their nominal parameter counts (±15%)."""
    expected = {
        "pixtral-12b": 12.25e9, "deepseek-v3-671b": 671e9,
        "kimi-k2-1t-a32b": 1.03e12, "qwen3-1.7b": 1.7e9, "minitron-8b": 8e9,
        "qwen2-72b": 72.7e9, "qwen1.5-110b": 111e9, "rwkv6-7b": 7.6e9,
        "recurrentgemma-2b": 2.7e9, "whisper-medium": 0.77e9,
    }
    for arch, want in expected.items():
        cfg = get_config(arch)
        n = count_params(get_api(cfg).decls(cfg))
        assert abs(n - want) / want < 0.15, (arch, n, want)


def test_long_500k_applicability():
    long = SHAPES["long_500k"]
    for arch in ARCH_IDS:
        ok, why = applicable(get_config(arch), long)
        if arch in ("rwkv6-7b", "recurrentgemma-2b"):
            assert ok
        else:
            assert not ok and why


def test_deepseek_mtp_head():
    """DeepSeek MTP (depth 1): extra predict-ahead loss trains and is finite."""
    base = get_smoke("deepseek-v3-671b")
    cfg = base.replace(mtp_depth=1)
    api = get_api(cfg)
    params = init_params(jax.random.PRNGKey(0), api.decls(cfg), jnp.float32)
    assert "mtp" in params
    batch = make_batch(cfg, 2, 16)
    loss, metrics = jax.jit(lambda p, b: api.loss(p, b, cfg))(params, batch)
    assert "mtp" in metrics and bool(jnp.isfinite(metrics["mtp"]))
    # mtp off -> loss excludes the extra term
    cfg0 = base
    p0 = {k: v for k, v in params.items() if k != "mtp"}
    loss0, m0 = jax.jit(lambda p, b: get_api(cfg0).loss(p, b, cfg0))(p0, batch)
    assert "mtp" not in m0
    assert float(loss) != float(loss0)
    # grads flow into the mtp params
    g = jax.grad(lambda p: api.loss(p, batch, cfg)[0])(params)
    gn = max(float(jnp.max(jnp.abs(x))) for x in jax.tree_util.tree_leaves(g["mtp"]))
    assert gn > 0


def test_blockwise_attention_matches_standard():
    """Flash-style blockwise attention == materialized softmax attention."""
    import repro.models.attention as A

    rng = np.random.default_rng(7)
    B, S, H, hd = 2, 96, 4, 16
    q, k, v = (
        jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32)) for _ in range(3)
    )
    q_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    for window in (None, 24):
        keep = A._mask(q_pos, S, causal=True, window=window)
        ref = A.mha(q, k, v, keep)
        for blk in (13, 32, 96):
            out = A.blockwise_mha(q, k, v, q_pos, causal=True, window=window, block=blk)
            np.testing.assert_allclose(np.asarray(ref), np.asarray(out), rtol=2e-5, atol=2e-5)
