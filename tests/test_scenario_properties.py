"""Hypothesis property tests for the scenario engine + economy invariants.

Event streams are pure state transforms (no settlement), so these run fast:
whatever events hypothesis throws at the economy, usage must stay inside
[0, capacity], the population must never silently lose or gain placed
agents, and capacity must stay non-negative.  Optional dependency — skipped
when hypothesis is absent (see requirements-dev.txt).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.economy import make_fleet_economy  # noqa: E402
from repro.core.scenarios import (  # noqa: E402
    Arrivals,
    BaseCostChange,
    CapacityShock,
    Departures,
    FlashCrowd,
    WeightingSwap,
)

N_CLUSTERS = 4

_events = st.one_of(
    st.builds(
        CapacityShock,
        epoch=st.just(0),
        cluster=st.integers(0, N_CLUSTERS - 1),
        scale=st.floats(0.0, 2.0, allow_nan=False),
        rtype=st.sampled_from([None, 0, 1, 2]),
    ),
    st.builds(
        FlashCrowd,
        epoch=st.just(0),
        value_scale=st.floats(0.1, 5.0, allow_nan=False),
        fraction=st.floats(0.0, 1.0, allow_nan=False),
        cluster=st.sampled_from([None, 0, 1]),
        seed=st.integers(0, 2**16),
    ),
    st.builds(
        Arrivals,
        epoch=st.just(0),
        num_agents=st.integers(1, 8),
        seed=st.integers(0, 2**16),
        value_mult=st.floats(0.5, 3.0, allow_nan=False),
    ),
    st.builds(
        Departures,
        epoch=st.just(0),
        fraction=st.floats(0.0, 1.0, allow_nan=False),
        cluster=st.sampled_from([None, 0, 2]),
        seed=st.integers(0, 2**16),
    ),
    st.builds(
        BaseCostChange,
        epoch=st.just(0),
        rtype=st.integers(0, 2),
        scale=st.floats(0.25, 4.0, allow_nan=False),
    ),
    st.builds(
        WeightingSwap,
        epoch=st.just(0),
        weighting=st.sampled_from(["exp", "logistic", "piecewise"]),
    ),
)


@settings(max_examples=40, deadline=None)
@given(events=st.lists(_events, max_size=6), seed=st.integers(0, 7))
def test_any_event_stream_keeps_economy_physical(events, seed):
    """Usage ∈ [0, capacity], capacity ≥ 0, population non-empty, and placed
    agents conserved through arbitrary event streams."""
    eco = make_fleet_economy(num_clusters=N_CLUSTERS, num_agents=12, seed=seed)
    for ev in events:
        placed_before = int((eco.pop.placed >= 0).sum())
        rep = ev.apply(eco)
        placed_after = int((eco.pop.placed >= 0).sum())
        assert placed_after == placed_before + rep.placed_added - rep.placed_removed
        assert (eco.usage >= -1e-9).all()
        assert (eco.usage <= eco.capacity + 1e-9).all()
        assert (eco.capacity >= 0).all()
        assert len(eco.pop) >= 1
        # population arrays stay consistent
        assert len(eco.pop) == eco.pop.placed.shape[0] == eco.pop.req.shape[0]
        assert (eco.pop.placed < eco.C).all() and (eco.pop.home < eco.C).all()


@settings(max_examples=25, deadline=None)
@given(
    frac=st.floats(0.0, 1.0, allow_nan=False),
    seed=st.integers(0, 2**16),
)
def test_departures_free_exactly_their_usage(frac, seed):
    """remove_agents subtracts exactly the leavers' held bundles (up to the
    0-floor) and reports the placed-leaver count faithfully."""
    eco = make_fleet_economy(num_clusters=N_CLUSTERS, num_agents=12, seed=3)
    rng = np.random.default_rng(seed)
    leave = rng.random(len(eco.pop)) < frac
    held = leave & (eco.pop.placed >= 0)
    expected = eco.usage.copy()
    np.add.at(expected, eco.pop.placed[held], -eco.pop.req[held])
    expected = np.maximum(expected, 0.0)
    n_placed = eco.remove_agents(leave)
    assert n_placed == int(held.sum())
    np.testing.assert_array_equal(eco.usage, expected)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), num=st.integers(1, 10))
def test_arrivals_conserve_existing_state(seed, num):
    """add_agents leaves existing agents' state untouched and appends."""
    eco = make_fleet_economy(num_clusters=N_CLUSTERS, num_agents=12, seed=5)
    placed0 = eco.pop.placed.copy()
    value0 = eco.pop.value.copy()
    from repro.core.markets import fleet_population

    newcomers = fleet_population(num, eco.C, seed=seed)
    eco.add_agents(newcomers)
    assert len(eco.pop) == 12 + num
    np.testing.assert_array_equal(eco.pop.placed[:12], placed0)
    np.testing.assert_array_equal(eco.pop.value[:12], value0)
    assert (eco.usage <= eco.capacity + 1e-9).all()
