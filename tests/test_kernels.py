"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.clock_bid_eval import bid_eval as pallas_bid_eval
from repro.kernels.wkv6 import wkv6 as pallas_wkv6


RNG = np.random.default_rng(0)


def _bid_case(U, B, R, dtype):
    bundles = (RNG.normal(size=(U, B, R)) * 3).astype(dtype)
    mask = RNG.random((U, B)) < 0.8
    mask[:, 0] = True
    pi = (RNG.normal(size=(U,)) * 5).astype(np.float32)
    prices = np.abs(RNG.normal(size=(R,))).astype(np.float32)
    return bundles, mask, pi, prices


@pytest.mark.parametrize("U,B,R", [(4, 1, 3), (33, 3, 18), (128, 8, 130), (517, 5, 200)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_clock_bid_eval_matches_oracle(U, B, R, dtype):
    bundles, mask, pi, prices = _bid_case(U, B, R, dtype)
    z0, c0 = ref.bid_eval(*map(jnp.asarray, (bundles, mask, pi, prices)))
    z1, c1 = pallas_bid_eval(*map(jnp.asarray, (bundles, mask, pi, prices)), interpret=True)
    np.testing.assert_allclose(np.asarray(z0), np.asarray(z1), rtol=3e-3, atol=3e-3)
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))


def test_clock_bid_eval_all_masked_user():
    bundles, mask, pi, prices = _bid_case(8, 2, 5, np.float32)
    mask[3, :] = False
    z0, c0 = ref.bid_eval(*map(jnp.asarray, (bundles, mask, pi, prices)))
    z1, c1 = pallas_bid_eval(*map(jnp.asarray, (bundles, mask, pi, prices)), interpret=True)
    assert c0[3] == -1 and c1[3] == -1
    np.testing.assert_allclose(np.asarray(z0), np.asarray(z1), rtol=1e-4, atol=1e-4)


def test_ops_backend_dispatch():
    bundles, mask, pi, prices = _bid_case(16, 2, 6, np.float32)
    za, _ = ops.bid_eval(*map(jnp.asarray, (bundles, mask, pi, prices)), backend="jnp")
    zb, _ = ops.bid_eval(*map(jnp.asarray, (bundles, mask, pi, prices)), backend="interpret")
    np.testing.assert_allclose(np.asarray(za), np.asarray(zb), rtol=1e-4, atol=1e-4)


def _wkv_case(T, H, K, V, dtype=np.float32, strong_decay=True):
    r = RNG.normal(size=(T, H, K)).astype(dtype)
    k = (RNG.normal(size=(T, H, K)) * 0.5).astype(dtype)
    v = RNG.normal(size=(T, H, V)).astype(dtype)
    scale = 1.0 if strong_decay else 0.1
    w = np.exp(-np.exp(RNG.normal(size=(T, H, K)) * scale)).astype(dtype)
    u = (RNG.normal(size=(H, K)) * 0.3).astype(dtype)
    s0 = (RNG.normal(size=(H, K, V)) * 0.2).astype(np.float32)
    return r, k, v, w, u, s0


@pytest.mark.parametrize("T,H,K,V,chunk", [
    (8, 1, 8, 8, 8), (16, 2, 8, 16, 8), (33, 1, 16, 16, 16),
    (64, 3, 32, 64, 32), (100, 2, 64, 64, 32),
])
def test_wkv6_pallas_matches_oracle(T, H, K, V, chunk):
    args = _wkv_case(T, H, K, V)
    o0, s0 = ref.wkv6(*map(jnp.asarray, args))
    o1, s1 = pallas_wkv6(*map(jnp.asarray, args), chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(o0), np.asarray(o1), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=3e-4, atol=3e-4)


def test_wkv6_pallas_bf16_inputs():
    """bf16 r/k/v/w inputs (the TPU layout), fp32 accumulation inside."""
    r, k, v, w, u, s0 = _wkv_case(32, 2, 16, 16)
    cast = lambda x: jnp.asarray(x, jnp.bfloat16)
    o0, sf0 = ref.wkv6(cast(r), cast(k), cast(v), cast(w), cast(u), jnp.asarray(s0))
    o1, sf1 = pallas_wkv6(
        cast(r), cast(k), cast(v), cast(w), cast(u), jnp.asarray(s0),
        chunk=16, interpret=True,
    )
    np.testing.assert_allclose(np.asarray(o0), np.asarray(o1), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(sf0), np.asarray(sf1), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_wkv6_chunked_jnp_matches_sequential(chunk):
    args = _wkv_case(50, 2, 16, 32)
    o0, s0 = ref.wkv6(*map(jnp.asarray, args))
    o1, s1 = ref.wkv6_chunked(*map(jnp.asarray, args), chunk=chunk)
    np.testing.assert_allclose(np.asarray(o0), np.asarray(o1), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s0), np.asarray(s1), rtol=3e-4, atol=3e-4)


def test_wkv6_state_continuity():
    """Running [0:T/2] then [T/2:T] from the carried state == one pass."""
    args = _wkv_case(40, 2, 16, 16)
    r, k, v, w, u, s0 = map(jnp.asarray, args)
    o_full, s_full = ref.wkv6_chunked(r, k, v, w, u, s0, chunk=8)
    o_a, s_a = ref.wkv6_chunked(r[:20], k[:20], v[:20], w[:20], u, s0, chunk=8)
    o_b, s_b = ref.wkv6_chunked(r[20:], k[20:], v[20:], w[20:], u, s_a, chunk=8)
    np.testing.assert_allclose(np.asarray(o_full), np.concatenate([o_a, o_b]), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_full), np.asarray(s_b), rtol=2e-4, atol=2e-4)


def test_wkv6_decode_step_equals_scan_step():
    """The closed-form S=1 decode update matches the sequential oracle."""
    args = _wkv_case(1, 2, 8, 8)
    o0, s0 = ref.wkv6(*map(jnp.asarray, args))
    o1, s1 = ref.wkv6_chunked(*map(jnp.asarray, args), chunk=1)
    np.testing.assert_allclose(np.asarray(o0), np.asarray(o1), rtol=1e-5, atol=1e-5)
