"""Checkpoint/restore, atomicity, async writes, elastic resharding, restart."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpoint import Checkpointer


def _tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4)},
        "state": {"m": jnp.ones((3, 4)), "step": jnp.int32(7)},
        "list": [jnp.zeros(2), jnp.ones(2)],
    }


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(5, tree, metadata={"note": "hi"}, block=True)
    restored, manifest = ck.restore_latest(tree)
    assert manifest["step"] == 5 and manifest["metadata"]["note"] == "hi"
    for a, b in zip(jax.tree_util.tree_leaves(tree), jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_picks_max(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    for s in (1, 9, 4):
        ck.save(s, tree, block=True)
    assert ck.latest_step() == 9


def test_atomic_no_partial_dirs(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(3, _tree(), block=True)
    names = os.listdir(tmp_path)
    assert "ckpt_00000003" in names
    assert not [n for n in names if n.startswith(".tmp")]


def test_async_save_then_wait(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree())  # async
    ck.wait()
    assert ck.latest_step() == 1


def test_dtype_cast_on_restore(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.ones((4,), jnp.float32)}
    ck.save(0, tree, block=True)
    target = {"w": jnp.zeros((4,), jnp.bfloat16)}
    restored, _ = ck.restore_latest(target)
    assert restored["w"].dtype == jnp.bfloat16


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint.checkpoint import Checkpointer
from repro.checkpoint.elastic import reshard

ckdir = sys.argv[1]
devs = np.asarray(jax.devices())

# save on a 4x2 mesh
mesh_a = Mesh(devs[:8].reshape(4, 2), ("data", "model"))
sh_a = NamedSharding(mesh_a, P("data", "model"))
x = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8), sh_a)
ck = Checkpointer(ckdir)
ck.save(1, {"x": x}, block=True)

# restore onto a 2x1 mesh (job lost 6 chips)
mesh_b = Mesh(devs[:2].reshape(2, 1), ("data", "model"))
sh_b = {"x": NamedSharding(mesh_b, P("data", "model"))}
restored, _ = ck.restore_latest({"x": x}, sh_b)
assert restored["x"].sharding.mesh.shape == {"data": 2, "model": 1}
np.testing.assert_array_equal(np.asarray(restored["x"]), np.arange(64).reshape(8, 8))

# grow back via reshard (job won more chips in the next auction)
big = reshard(restored, {"x": sh_a})
np.testing.assert_array_equal(np.asarray(big["x"]), np.arange(64).reshape(8, 8))
print("ELASTIC_OK")
"""


def test_elastic_reshard_across_meshes(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", ELASTIC_SCRIPT, str(tmp_path)],
        capture_output=True, text=True, env=env, cwd=os.getcwd(), timeout=300,
    )
    assert "ELASTIC_OK" in out.stdout, out.stdout + out.stderr


@pytest.mark.slow
def test_supervisor_restarts_after_injected_fault(tmp_path):
    """End-to-end fault tolerance: crash at step 6, restart, finish 12 steps."""
    from repro.launch.supervisor import run_supervised

    env_backup = os.environ.get("FAULT_STEP")
    os.environ["FAULT_STEP"] = "6"
    try:
        rc = run_supervised(
            [
                "--arch",
                "qwen3-1.7b",
                "--smoke",
                "--steps",
                "12",
                "--batch",
                "2",
                "--seq",
                "32",
                "--ckpt-every",
                "2",
                "--metrics",
                str(tmp_path / "m.jsonl"),
            ],
            ckpt_dir=str(tmp_path / "ck"),
            max_restarts=2,
            deadline_s=600,
        )
    finally:
        if env_backup is None:
            os.environ.pop("FAULT_STEP", None)
        else:
            os.environ["FAULT_STEP"] = env_backup
    assert rc == 0
    steps = [json.loads(l)["step"] for l in open(tmp_path / "m.jsonl")]
    assert 6 in steps and 11 in steps  # crashed step was re-run after restart


_HANG_TRAINER = '''
"""Stub trainer: beats once, hangs on attempt 1, exits clean on attempt 2."""
import argparse, os, sys, time

ap = argparse.ArgumentParser()
ap.add_argument("--ckpt-dir")
ap.add_argument("--heartbeat")
args, _ = ap.parse_known_args()
os.makedirs(args.ckpt_dir, exist_ok=True)
with open(args.heartbeat, "w") as f:
    f.write("beat")
marker = os.path.join(args.ckpt_dir, "attempted")
if os.path.exists(marker):
    sys.exit(0)  # the restarted attempt finishes cleanly
with open(marker, "w") as f:
    f.write("1")
while True:
    time.sleep(60)  # hang: the heartbeat above is the last one ever written
'''


def _temp_hb_dirs():
    import glob
    import tempfile

    return set(glob.glob(os.path.join(tempfile.gettempdir(), "repro_hb_*")))


def test_supervisor_restarts_on_hang_and_cleans_heartbeat(tmp_path, monkeypatch):
    """The missing half of the supervision story: a *hung* trainer (stale
    heartbeat, process alive) is killed and restarted — and the heartbeat
    temp directory is removed afterwards (it used to leak one mkdtemp per
    supervised run)."""
    from repro.launch.supervisor import run_supervised

    (tmp_path / "hang_trainer.py").write_text(_HANG_TRAINER)
    monkeypatch.setenv(
        "PYTHONPATH",
        str(tmp_path) + os.pathsep + os.environ.get("PYTHONPATH", ""),
    )
    before = _temp_hb_dirs()
    rc = run_supervised(
        [], ckpt_dir=str(tmp_path / "ck"), max_restarts=2,
        deadline_s=2.0, poll_s=0.2, module="hang_trainer",
    )
    assert rc == 0  # hang detected -> killed -> restart finished cleanly
    assert (tmp_path / "ck" / "attempted").exists()
    assert _temp_hb_dirs() <= before  # no leaked heartbeat directories


def test_supervisor_gives_up_after_max_restarts_and_cleans_up(tmp_path, monkeypatch):
    (tmp_path / "always_hang.py").write_text(
        _HANG_TRAINER.replace("sys.exit(0)", "pass")
    )
    monkeypatch.setenv(
        "PYTHONPATH",
        str(tmp_path) + os.pathsep + os.environ.get("PYTHONPATH", ""),
    )
    from repro.launch.supervisor import run_supervised

    before = _temp_hb_dirs()
    rc = run_supervised(
        [], ckpt_dir=str(tmp_path / "ck"), max_restarts=1,
        deadline_s=1.0, poll_s=0.2, module="always_hang",
    )
    assert rc == 1
    assert _temp_hb_dirs() <= before
