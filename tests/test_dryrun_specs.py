"""Dry-run spec machinery: input shapes, cache layouts, sharding helpers.

Includes regressions for the §Perf findings:
  * audio decode must lower a (B, 1) token — not the full sequence
    (the whisper decode_32k cell was 32,000× collective-heavier before);
  * cache layout logic must be identical between launch specs and in-model
    constraints (a mismatch makes the partitioner all-gather the cache);
  * shard() must never force full replication and must drop duplicate axes.
"""
import os
import subprocess
import sys

import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES
from repro.launch.specs import input_specs


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_input_is_one_token(arch):
    cfg = get_config(arch)
    for shape_name in ("decode_32k", "long_500k"):
        specs = input_specs(cfg, SHAPES[shape_name])
        assert specs["tokens"].shape == (SHAPES[shape_name].global_batch, 1), (
            arch, shape_name, specs["tokens"].shape,
        )
        assert "frames" not in specs  # audio decode reads the cross cache


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_prefill_input_shapes(arch):
    cfg = get_config(arch)
    tr = input_specs(cfg, SHAPES["train_4k"])
    pf = input_specs(cfg, SHAPES["prefill_32k"])
    assert "labels" in tr and "labels" not in pf
    if cfg.vlm_patches:
        assert tr["tokens"].shape[1] == 4096 - cfg.vlm_patches
        assert tr["image_embeds"].shape[1] == cfg.vlm_patches
    elif cfg.family == "audio":
        assert tr["frames"].shape[1] == cfg.encdec.num_frames
    else:
        assert tr["tokens"].shape == (256, 4096)


SHARDING_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.sharding import use_mesh, shard, replicate, shard_cache_kv, shard_cache_latent

mesh = jax.make_mesh((4, 4), ("data", "model"))

with use_mesh(mesh, {"seq": "model"}):  # SP rules: batch->data, seq->model
    # duplicate axis: heads would also map to model; later dup must drop
    x = jnp.zeros((8, 16, 8, 4))
    y = jax.jit(lambda a: shard(a, "batch", "seq", "heads", None))(x)
    assert "model" in str(y.sharding.spec), y.sharding

    # all-indivisible => no-op (never force replication)
    z = jnp.zeros((3, 5))
    out = jax.jit(lambda a: shard(a, "batch", "seq"))(z)

    # adaptive cache: kv heads divisible -> heads sharded
    c1 = jax.jit(shard_cache_kv)(jnp.zeros((8, 32, 4, 8)))
    assert c1.sharding.spec[2] == "model", c1.sharding
    # kv heads NOT divisible -> seq sharded
    c2 = jax.jit(shard_cache_kv)(jnp.zeros((8, 32, 2, 8)))
    assert c2.sharding.spec[1] == "model", c2.sharding
    # latent cache: seq sharded
    c3 = jax.jit(shard_cache_latent)(jnp.zeros((8, 32, 6)))
    assert c3.sharding.spec[1] == "model", c3.sharding
    # replicate forces P()
    r = jax.jit(replicate)(jnp.zeros((8, 8)))
    assert all(s is None for s in (list(r.sharding.spec) + [None])), r.sharding
print("SHARDING_OK")
"""


def test_sharding_helpers_on_mesh():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", SHARDING_SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.getcwd(), timeout=300,
    )
    assert "SHARDING_OK" in out.stdout, out.stdout + "\n" + out.stderr


DRYRUN_SCRIPT = r"""
import sys
sys.argv = ["dryrun"]
from repro.launch import dryrun as dr  # sets XLA_FLAGS to 512 before jax init
from repro.configs.shapes import SHAPES

rec = dr.lower_cell("qwen3-1.7b", SHAPES["decode_32k"], multi_pod=False)
assert rec["status"] == "ok"
r = rec["roofline"]
assert r["hlo_flops"] > 0 and r["hlo_bytes"] > 0
assert r["bottleneck"] in ("compute", "memory", "collective")
# decode of a 1.7B model must not move more than ~1 GB/chip of collectives
assert r["coll_bytes_per_chip"] < 2e9, r["coll_bytes_per_chip"]
print("DRYRUN_OK")
"""


@pytest.mark.slow
def test_dryrun_cell_end_to_end():
    """Full dry-run machinery on one real cell (512 fake devices, subprocess)."""
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", DRYRUN_SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.getcwd(), timeout=580,
    )
    assert "DRYRUN_OK" in out.stdout, out.stdout[-2000:] + "\n" + out.stderr[-2000:]
