"""TBBL-style bid tree flattening (paper §II)."""
import pytest

from repro.core import All, BundleExplosion, OneOf, Res, flatten, pool_index


IDX = pool_index(["c1/cpu", "c1/ram", "c2/cpu", "c2/ram"])


def test_leaf():
    (q,) = flatten(Res("c1/cpu", 5), IDX)
    assert q.tolist() == [5, 0, 0, 0]


def test_and_sums():
    (q,) = flatten(All(Res("c1/cpu", 5), Res("c1/ram", 2)), IDX)
    assert q.tolist() == [5, 2, 0, 0]


def test_xor_alternatives():
    qs = flatten(
        OneOf(
            All(Res("c1/cpu", 5), Res("c1/ram", 2)),
            All(Res("c2/cpu", 5), Res("c2/ram", 2)),
        ),
        IDX,
    )
    assert len(qs) == 2
    assert qs[0].tolist() == [5, 2, 0, 0]
    assert qs[1].tolist() == [0, 0, 5, 2]


def test_and_of_xor_cartesian():
    qs = flatten(
        All(
            OneOf(Res("c1/cpu", 1), Res("c2/cpu", 1)),
            OneOf(Res("c1/ram", 4), Res("c2/ram", 4)),
        ),
        IDX,
    )
    assert len(qs) == 4
    assert any(q.tolist() == [1, 0, 0, 4] for q in qs)  # cross-cluster combos exist


def test_sell_side_negative():
    (q,) = flatten(Res("c1/cpu", -3), IDX)
    assert q.tolist() == [-3, 0, 0, 0]


def test_explosion_guard():
    inner = OneOf(*[Res("c1/cpu", i + 1) for i in range(9)])
    with pytest.raises(BundleExplosion):
        flatten(All(inner, inner, inner), IDX, max_bundles=64)


def test_unknown_pool():
    with pytest.raises(KeyError):
        flatten(Res("nope", 1), IDX)
