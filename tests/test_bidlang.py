"""TBBL-style bid tree flattening (paper §II)."""
import numpy as np
import pytest

from repro.core import (
    All,
    BundleExplosion,
    OneOf,
    Res,
    csr_from_padded,
    flatten,
    flatten_sparse,
    pack_bids_csr,
    pack_bids_sparse,
    padded_from_csr,
    pool_index,
)


IDX = pool_index(["c1/cpu", "c1/ram", "c2/cpu", "c2/ram"])


def test_leaf():
    (q,) = flatten(Res("c1/cpu", 5), IDX)
    assert q.tolist() == [5, 0, 0, 0]


def test_and_sums():
    (q,) = flatten(All(Res("c1/cpu", 5), Res("c1/ram", 2)), IDX)
    assert q.tolist() == [5, 2, 0, 0]


def test_xor_alternatives():
    qs = flatten(
        OneOf(
            All(Res("c1/cpu", 5), Res("c1/ram", 2)),
            All(Res("c2/cpu", 5), Res("c2/ram", 2)),
        ),
        IDX,
    )
    assert len(qs) == 2
    assert qs[0].tolist() == [5, 2, 0, 0]
    assert qs[1].tolist() == [0, 0, 5, 2]


def test_and_of_xor_cartesian():
    qs = flatten(
        All(
            OneOf(Res("c1/cpu", 1), Res("c2/cpu", 1)),
            OneOf(Res("c1/ram", 4), Res("c2/ram", 4)),
        ),
        IDX,
    )
    assert len(qs) == 4
    assert any(q.tolist() == [1, 0, 0, 4] for q in qs)  # cross-cluster combos exist


def test_sell_side_negative():
    (q,) = flatten(Res("c1/cpu", -3), IDX)
    assert q.tolist() == [-3, 0, 0, 0]


def test_explosion_guard():
    inner = OneOf(*[Res("c1/cpu", i + 1) for i in range(9)])
    with pytest.raises(BundleExplosion):
        flatten(All(inner, inner, inner), IDX, max_bundles=64)


def test_unknown_pool():
    with pytest.raises(KeyError):
        flatten(Res("nope", 1), IDX)


# ---------------------------------------------------------------------------
# sparse flattening + direct variable-K CSR packing
# ---------------------------------------------------------------------------

TREES = [
    Res("c1/cpu", 5),
    All(Res("c1/cpu", 5), Res("c1/ram", 2)),
    OneOf(
        All(Res("c1/cpu", 5), Res("c1/ram", 2)),
        All(Res("c2/cpu", 5), Res("c2/ram", 2)),
        Res("c2/ram", 7),
    ),
    All(
        OneOf(Res("c1/cpu", 1), Res("c2/cpu", 1)),
        OneOf(Res("c1/ram", 4), Res("c2/ram", 4)),
    ),
    Res("c1/cpu", -3),  # sell side
    All(Res("c1/cpu", 5), Res("c1/cpu", -5)),  # cancels to the empty bundle
]


def test_flatten_sparse_matches_dense():
    """Sparse pairs densify to exactly the dense flattening, tree by tree."""
    for tree in TREES:
        dense = flatten(tree, IDX)
        sparse = flatten_sparse(tree, IDX)
        assert len(dense) == len(sparse)
        for q, (ii, vv) in zip(dense, sparse):
            assert ii.dtype == np.int32 and vv.dtype == np.float32
            assert (np.diff(ii) > 0).all()  # strictly ascending, no dups
            assert (vv != 0).all()  # exact zeros dropped
            back = np.zeros_like(q)
            back[ii] = vv
            np.testing.assert_array_equal(back, q)


def test_flatten_sparse_guards():
    inner = OneOf(*[Res("c1/cpu", i + 1) for i in range(9)])
    with pytest.raises(BundleExplosion):
        flatten_sparse(All(inner, inner, inner), IDX, max_bundles=64)
    with pytest.raises(KeyError):
        flatten_sparse(Res("nope", 1), IDX)


def _books(trees):
    lists = [flatten_sparse(t, IDX) for t in trees]
    pis = [[10.0] * max(len(bl) for bl in lists)] * len(lists)
    base = np.full(len(IDX), 0.5, np.float32)
    return lists, pis, base


def test_pack_bids_csr_direct_matches_padded_path():
    """Direct CSR assembly == the padded pack converted, field for field.

    This pins the variable-K fast path (no (U, B, K_max) intermediate) to
    the padded oracle: flat streams, offsets, k_bound, supply_scale, mask,
    and the padded reconstruction all bit-identical.
    """
    lists, pis, base = _books(TREES)
    direct = pack_bids_csr(lists, pis, base_cost=base)
    oracle = csr_from_padded(pack_bids_sparse(lists, pis, base_cost=base))
    assert direct.k_bound == oracle.k_bound
    assert direct.num_resources == oracle.num_resources
    for f in ("idx", "val", "rows", "offsets", "bundle_mask", "pi",
              "base_cost", "supply_scale"):
        va, vb = np.asarray(getattr(direct, f)), np.asarray(getattr(oracle, f))
        assert va.dtype == vb.dtype, f
        np.testing.assert_array_equal(va, vb, err_msg=f)
    pa, pb = padded_from_csr(direct), padded_from_csr(oracle)
    for f in ("idx", "val", "bundle_mask", "pi"):
        np.testing.assert_array_equal(
            np.asarray(getattr(pa, f)), np.asarray(getattr(pb, f)), err_msg=f
        )


def test_pack_bids_csr_dense_and_sparse_inputs_agree():
    """Dense (R,) rows and (idx, val) pairs of the same trees pack alike."""
    for tree in TREES:
        dense_book = pack_bids_csr(
            [flatten(tree, IDX)],
            [[1.0] * len(flatten(tree, IDX))],
            base_cost=np.ones(len(IDX), np.float32),
        )
        sparse_book = pack_bids_csr(
            [flatten_sparse(tree, IDX)],
            [[1.0] * len(flatten(tree, IDX))],
            base_cost=np.ones(len(IDX), np.float32),
        )
        np.testing.assert_array_equal(
            np.asarray(dense_book.idx), np.asarray(sparse_book.idx)
        )
        np.testing.assert_array_equal(
            np.asarray(dense_book.val), np.asarray(sparse_book.val)
        )
        np.testing.assert_array_equal(
            np.asarray(dense_book.offsets), np.asarray(sparse_book.offsets)
        )


def test_pack_bids_csr_no_padding_blowup():
    """One dense bundle next to many singletons costs O(nnz), not U·B·K_max."""
    r = 64
    pidx = pool_index([f"p{i}" for i in range(r)])
    wide = All(*[Res(f"p{i}", 1.0) for i in range(r)])  # one K=64 bundle
    skinny = [Res(f"p{i % r}", 2.0) for i in range(40)]  # forty K=1 bundles
    lists = [flatten_sparse(wide, pidx)] + [flatten_sparse(s, pidx) for s in skinny]
    pis = [[1.0]] * len(lists)
    book = pack_bids_csr(lists, pis, base_cost=np.ones(r, np.float32))
    assert book.k_bound == r
    assert int(np.asarray(book.idx).shape[0]) == r + 40  # flat nnz, no K_max rows
    oracle = csr_from_padded(
        pack_bids_sparse(lists, pis, base_cost=np.ones(r, np.float32))
    )
    np.testing.assert_array_equal(np.asarray(book.idx), np.asarray(oracle.idx))
    np.testing.assert_array_equal(np.asarray(book.val), np.asarray(oracle.val))
    np.testing.assert_array_equal(
        np.asarray(book.supply_scale), np.asarray(oracle.supply_scale)
    )
