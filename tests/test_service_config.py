"""ServiceConfig: validation at construction + the legacy-kwarg shim.

The consolidated config is the one home for every MarketService knob; a
bad value must fail when the config is built, not at the first tick, and
the old per-kwarg constructor surface must keep working for one release
behind a DeprecationWarning that fires exactly once per process.
"""
import warnings

import numpy as np
import pytest

from repro.serve import ServiceConfig
from repro.serve.market import MarketService


def test_defaults_validate():
    cfg = ServiceConfig()
    assert cfg.wal_sync == "flush"
    assert cfg.checkpoint_interval == 1
    assert cfg.checkpoint_full_every == 8
    assert not cfg.async_commit
    assert cfg.clock is None and cfg.rows_cap is None


def test_frozen():
    cfg = ServiceConfig()
    with pytest.raises(Exception):
        cfg.max_pending = 5


def test_replace_revalidates():
    cfg = ServiceConfig().replace(max_history=7)
    assert cfg.max_history == 7
    with pytest.raises(ValueError, match="max_history"):
        cfg.replace(max_history=0)


@pytest.mark.parametrize(
    "bad",
    [
        dict(wal_sync="eventually"),
        dict(max_pending=0),
        dict(max_history=0),
        dict(checkpoint_keep=0),
        dict(checkpoint_interval=0),
        dict(checkpoint_full_every=0),
        dict(max_escalations=-1),
        dict(rows_cap=0),
        dict(settle_blocks=0),
        dict(max_quantity=0.0),
        dict(tick_deadline_s=-1.0),
        dict(backoff_base_s=0.0),
        dict(backoff_cap_s=-1.0),
        dict(async_commit=True),  # requires checkpoint_dir
    ],
)
def test_invalid_values_rejected_at_config_time(bad):
    with pytest.raises(ValueError):
        ServiceConfig(**bad)


def test_async_commit_requires_checkpoint_dir(tmp_path):
    cfg = ServiceConfig(async_commit=True, checkpoint_dir=str(tmp_path))
    assert cfg.async_commit


def test_unknown_field_rejected():
    with pytest.raises(TypeError):
        ServiceConfig(wal_pth="typo")


# -- deprecation shim ---------------------------------------------------------


def _svc(**kw):
    return MarketService(np.ones(2, np.float32), num_bundles=1, k_bound=1, **kw)


def test_legacy_kwargs_warn_exactly_once_and_apply():
    MarketService._legacy_kwargs_warned = False  # order-independent test
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        svc = _svc(rows_cap=4, max_pending=17)
        _svc(max_history=3)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "deprecated" in str(dep[0].message)
    # the shimmed kwargs land in the validated config
    assert svc.book.rows_cap == 4
    assert svc.max_pending == 17
    assert svc.config.max_pending == 17


def test_legacy_kwargs_fold_into_explicit_config():
    MarketService._legacy_kwargs_warned = False
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        svc = _svc(config=ServiceConfig(max_history=9), rows_cap=4)
    assert svc.max_history == 9  # from the config
    assert svc.book.rows_cap == 4  # from the legacy kwarg


def test_unknown_legacy_kwarg_rejected():
    MarketService._legacy_kwargs_warned = True  # silence the shim
    with pytest.raises(TypeError):
        _svc(row_cap=4)  # typo'd name fails loudly, not silently ignored


def test_legacy_kwargs_validated_like_config():
    MarketService._legacy_kwargs_warned = True
    with pytest.raises(ValueError, match="wal_sync"):
        _svc(wal_sync="eventually")


def test_config_object_attached_to_service():
    svc = _svc()
    assert isinstance(svc.config, ServiceConfig)
    assert svc.checkpoint_interval == 1
    assert not svc.async_commit
