"""Sharding helpers, provisioner mesh planning, data pipeline determinism."""
import numpy as np
import pytest

from repro.core.provisioner import DeviceGrant, grant_to_mesh, plan_mesh_shape
from repro.data.pipeline import SyntheticLM
from repro.models import ModelConfig
from repro.models.params import (
    ParamDecl,
    count_params,
    pspec_tree,
    validated_pspec_tree,
)


class TestMeshPlanning:
    def test_min_model_respected(self):
        d, m = plan_mesh_shape(256, min_model=16)
        assert m >= 16 and d * m == 256

    def test_prefers_small_tp(self):
        d, m = plan_mesh_shape(64, min_model=1)
        assert m == 1 and d == 64

    def test_non_pow2_grant(self):
        d, m = plan_mesh_shape(96, min_model=4)
        assert d * m <= 96 and m >= 4

    def test_empty_grant_raises(self):
        with pytest.raises(ValueError):
            plan_mesh_shape(0)

    def test_grant_to_mesh_degrades_to_local_devices(self):
        mesh = grant_to_mesh(DeviceGrant("job", "c1", chips=512))
        assert mesh.devices.size >= 1  # CPU container has 1 device


class TestPspecs:
    def test_stacked_layers_never_sharded(self):
        d = ParamDecl((4, 128, 256), ("layers", "embed", "ff"))
        spec = pspec_tree(d)
        assert spec[0] is None and spec[2] == "model"

    def test_validated_drops_indivisible(self):
        class FakeMesh:
            axis_names = ("data", "model")
            devices = np.zeros((4, 16))

        d = ParamDecl((10, 48), ("kv_heads", "ff"))  # 10 % 16 != 0
        spec = validated_pspec_tree(d, FakeMesh(), None)
        assert tuple(spec) == (None, "model")

    def test_count_params(self):
        d = {"a": ParamDecl((3, 4), (None, None)), "b": ParamDecl((5,), (None,))}
        assert count_params(d) == 17


class TestDataPipeline:
    CFG = ModelConfig(
        name="t", family="dense", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=32, vocab_size=101, act_dtype="float32",
    )

    def test_deterministic_per_step(self):
        p = SyntheticLM(self.CFG, batch=4, seq=8, seed=3)
        a, b = p(5), p(5)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        p = SyntheticLM(self.CFG, batch=4, seq=8, seed=3)
        assert not np.array_equal(p(1)["tokens"], p(2)["tokens"])

    def test_shards_disjoint_streams(self):
        p = SyntheticLM(self.CFG, batch=4, seq=8, seed=3)
        a = p(0, shard=0, num_shards=2)
        b = p(0, shard=1, num_shards=2)
        assert a["tokens"].shape == (2, 8)
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_vocab_bounds(self):
        p = SyntheticLM(self.CFG, batch=4, seq=8)
        t = p(0)["tokens"]
        assert t.min() >= 0 and t.max() < 101

    def test_memmap_pipeline(self, tmp_path):
        from repro.data.pipeline import MemmapLM

        toks = np.arange(1000, dtype=np.int32) % 101
        path = tmp_path / "corpus.bin"
        toks.tofile(path)
        p = MemmapLM(str(path), self.CFG, batch=4, seq=8, seed=0)
        a, b = p(0), p(0)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
