"""Pipeline parallelism: GPipe schedule == sequential reference, fwd + grad.

Runs in a subprocess with 4 fake devices (the test file itself must not
pollute the session's device count)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.train.pipeline import make_pipelined_loss, pipeline_apply

S, M, MB, D = 4, 8, 2, 16
rng = np.random.default_rng(0)
mesh = Mesh(np.asarray(jax.devices()[:S]).reshape(S), ("pod",))

# homogeneous stage: y = tanh(x @ w + b)
stages = {
    "w": jnp.asarray(rng.normal(size=(S, D, D)).astype(np.float32) * 0.3),
    "b": jnp.asarray(rng.normal(size=(S, D)).astype(np.float32) * 0.1),
}
head = {"v": jnp.asarray(rng.normal(size=(D,)).astype(np.float32))}
x = jnp.asarray(rng.normal(size=(M, MB, D)).astype(np.float32))
y = jnp.asarray(rng.normal(size=(M, MB)).astype(np.float32))

def stage_fn(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

def loss_head(p, outs, tgt):
    pred = jnp.einsum("mbd,d->mb", outs, p["v"])
    return jnp.mean((pred - tgt) ** 2)

# sequential reference
def ref_loss(params, batch):
    h = batch["x"]
    for s in range(S):
        p = jax.tree_util.tree_map(lambda a: a[s], params["stages"])
        h = stage_fn(p, h)
    return loss_head(params["head"], h, batch["y"])

params = {"stages": stages, "head": head}
batch = {"x": x, "y": y}
pl = make_pipelined_loss(stage_fn, loss_head, mesh, "pod")

l_ref = ref_loss(params, batch)
l_pp = jax.jit(pl)(params, batch)
np.testing.assert_allclose(float(l_ref), float(l_pp), rtol=1e-5)

g_ref = jax.grad(ref_loss)(params, batch)
g_pp = jax.jit(jax.grad(pl))(params, batch)
for a, b in zip(jax.tree_util.tree_leaves(g_ref), jax.tree_util.tree_leaves(g_pp)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)
print("PIPELINE_OK")
"""


def test_gpipe_matches_sequential_forward_and_grad():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT], capture_output=True, text=True,
        env=env, cwd=os.getcwd(), timeout=600,
    )
    assert "PIPELINE_OK" in out.stdout, out.stdout + "\n" + out.stderr
