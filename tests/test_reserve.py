"""§IV.A weighting-function properties 1-5 for every curve family."""
import numpy as np
import pytest

from repro.core import CURVE_FAMILIES, ResourcePool, reserve_prices


@pytest.mark.parametrize("name", list(CURVE_FAMILIES))
class TestWeightingProperties:
    def test_p1_monotone(self, name):
        phi = CURVE_FAMILIES[name]
        psi = np.linspace(0, 1, 201, dtype=np.float32)
        vals = np.asarray(phi(psi))
        assert (np.diff(vals) >= -1e-6).all()

    def test_p2_overutilized_above_one(self, name):
        phi = CURVE_FAMILIES[name]
        t = getattr(phi, "target")
        psi = np.linspace(t + 0.02, 1.0, 50, dtype=np.float32)
        assert (np.asarray(phi(psi)) > 1.0 - 1e-5).all()

    def test_p3_underutilized_at_most_one(self, name):
        phi = CURVE_FAMILIES[name]
        t = getattr(phi, "target")
        psi = np.linspace(0.0, t, 50, dtype=np.float32)
        assert (np.asarray(phi(psi)) <= 1.0 + 1e-5).all()

    def test_p4_congested_spread_dominates(self, name):
        phi = CURVE_FAMILIES[name]
        hi = float(phi(np.float32(0.99))) / float(phi(np.float32(0.80)))
        lo = float(phi(np.float32(0.40))) / float(phi(np.float32(0.15)))
        assert hi > 2.0 * lo  # "significantly greater"

    def test_p5_bounded_ratio(self, name):
        phi = CURVE_FAMILIES[name]
        k = getattr(phi, "k")
        ratio = float(phi(np.float32(1.0))) / float(phi(np.float32(0.0)))
        assert ratio == pytest.approx(k, rel=0.05)


def test_reserve_price_eq4():
    pools = [
        ResourcePool("a", "cpu", base_cost=2.0, utilization=0.95),
        ResourcePool("b", "cpu", base_cost=2.0, utilization=0.10),
    ]
    pr = reserve_prices(pools)
    assert pr[0] > 2.0 > pr[1] > 0.0
