"""Golden regression: settlement output pinned for make_fleet_economy.

Each fixture in tests/golden/ snapshots three epochs of EpochStats (prices,
reserves, premiums, migrations, surplus) for one seed.  A refactor that is
supposed to be settlement-neutral must reproduce them exactly; a deliberate
numerics change regenerates them with ``python tests/update_golden.py``
(and says why in the commit).
"""
import json
import math
import os

import numpy as np
import pytest

from repro.core.economy import make_fleet_economy

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
SEEDS = (0, 3, 7)


def _load(seed):
    path = os.path.join(GOLDEN_DIR, f"economy_seed{seed}.json")
    with open(path) as f:
        return json.load(f)


def _check_scalar(actual, expected, ctx):
    if isinstance(expected, float) and math.isnan(expected):
        assert math.isnan(actual), ctx
    else:
        assert actual == expected, (ctx, actual, expected)


@pytest.mark.parametrize("seed", SEEDS)
def test_epochstats_match_golden(seed):
    golden = _load(seed)
    eco = make_fleet_economy(seed=seed)
    for rec in golden["stats"]:
        s = eco.run_epoch()
        ctx = (seed, rec["epoch"])
        # float(np.float32) widens exactly, so equality here is bit-exact
        np.testing.assert_array_equal(
            s.prices.astype(np.float64), np.asarray(rec["prices"]),
            err_msg=f"{ctx} prices",
        )
        np.testing.assert_array_equal(
            s.reserve.astype(np.float64), np.asarray(rec["reserve"]),
            err_msg=f"{ctx} reserve",
        )
        for k in ("gamma_median", "gamma_mean", "pct_settled", "surplus",
                  "value_of_trade"):
            _check_scalar(float(getattr(s, k)), rec[k], (*ctx, k))
        for k in ("epoch", "migrations", "rounds"):
            _check_scalar(int(getattr(s, k)), rec[k], (*ctx, k))
        for k in ("converged", "system_ok"):
            _check_scalar(bool(getattr(s, k)), rec[k], (*ctx, k))
