"""Golden regression: settlement output pinned for make_fleet_economy.

Each fixture in tests/golden/ snapshots three epochs of EpochStats (prices,
reserves, premiums, migrations, surplus) for one seed.  A refactor that is
supposed to be settlement-neutral must reproduce them exactly; a deliberate
numerics change regenerates them with ``python tests/update_golden.py``
(and says why in the commit).

Two sets are pinned per seed: the default cold-start economy and the
``warm_start=True`` economy (epoch 0 identical by construction — there is
no previous clearing point yet — later epochs seeded with
max(p_prev, reserve)), so neither path can drift while the other stays
green.
"""
import json
import math
import os

import numpy as np
import pytest

from repro.core.economy import make_fleet_economy

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")
SEEDS = (0, 3, 7)


def _load(seed, warm):
    stem = "economy_warm" if warm else "economy"
    path = os.path.join(GOLDEN_DIR, f"{stem}_seed{seed}.json")
    with open(path) as f:
        return json.load(f)


def _check_scalar(actual, expected, ctx):
    if isinstance(expected, float) and math.isnan(expected):
        assert math.isnan(actual), ctx
    else:
        assert actual == expected, (ctx, actual, expected)


@pytest.mark.parametrize("warm", [False, True], ids=["cold", "warm"])
@pytest.mark.parametrize("seed", SEEDS)
def test_epochstats_match_golden(seed, warm):
    golden = _load(seed, warm)
    eco = make_fleet_economy(seed=seed, warm_start=warm)
    for rec in golden["stats"]:
        s = eco.run_epoch()
        ctx = (seed, warm, rec["epoch"])
        # float(np.float32) widens exactly, so equality here is bit-exact
        np.testing.assert_array_equal(
            s.prices.astype(np.float64), np.asarray(rec["prices"]),
            err_msg=f"{ctx} prices",
        )
        np.testing.assert_array_equal(
            s.reserve.astype(np.float64), np.asarray(rec["reserve"]),
            err_msg=f"{ctx} reserve",
        )
        for k in ("gamma_median", "gamma_mean", "pct_settled", "surplus", "value_of_trade"):
            _check_scalar(float(getattr(s, k)), rec[k], (*ctx, k))
        for k in ("epoch", "migrations", "rounds"):
            _check_scalar(int(getattr(s, k)), rec[k], (*ctx, k))
        for k in ("converged", "system_ok", "warm_started"):
            _check_scalar(bool(getattr(s, k)), rec[k], (*ctx, k))


def test_migration_relief_matches_golden():
    """The policy-driven congestion-relief trajectory is pinned exactly —
    prices, per-epoch utilization (the drain itself), premiums, migrations.
    A change here means adaptive-bidder behavior moved, not just packing."""
    from repro.core.scenarios import migration_relief, run_scenario

    with open(os.path.join(GOLDEN_DIR, "scenario_migration_relief.json")) as f:
        golden = json.load(f)
    eco, sc = migration_relief()
    assert sc.epochs == golden["epochs"]
    res = run_scenario(eco, sc)
    assert len(res.stats) == len(golden["stats"])
    for s, rec in zip(res.stats, golden["stats"]):
        ctx = ("migration_relief", rec["epoch"])
        for k in ("psi", "prices", "reserve"):
            np.testing.assert_array_equal(
                np.asarray(getattr(s, k), np.float64), np.asarray(rec[k]),
                err_msg=f"{ctx} {k}",
            )
        for k in ("gamma_median", "gamma_mean", "pct_settled", "surplus", "value_of_trade"):
            _check_scalar(float(getattr(s, k)), rec[k], (*ctx, k))
        for k in ("epoch", "migrations", "rounds"):
            _check_scalar(int(getattr(s, k)), rec[k], (*ctx, k))
        for k in ("converged", "system_ok"):
            _check_scalar(bool(getattr(s, k)), rec[k], (*ctx, k))


@pytest.mark.parametrize(
    "name", ["region_loss", "region_recovery", "unreliable_supply"]
)
def test_fault_scenario_matches_golden(name):
    """The fault-injection trajectories are pinned exactly — prices, psi,
    AND the degraded-mode telemetry (evictions, clawback, compensation,
    seller/pool failures, escalations) plus the final reliability EMAs.
    A change here means the failure-recovery machinery moved."""
    from repro.core.scenarios import SCENARIOS, run_scenario

    with open(os.path.join(GOLDEN_DIR, f"scenario_{name}.json")) as f:
        golden = json.load(f)
    eco, sc = SCENARIOS[name]()
    assert sc.epochs == golden["epochs"]
    res = run_scenario(eco, sc)
    assert len(res.stats) == len(golden["stats"])
    for s, rec in zip(res.stats, golden["stats"]):
        ctx = (name, rec["epoch"])
        for k in ("psi", "prices", "reserve"):
            np.testing.assert_array_equal(
                np.asarray(getattr(s, k), np.float64), np.asarray(rec[k]),
                err_msg=f"{ctx} {k}",
            )
        for k in (
            "gamma_median",
            "pct_settled",
            "surplus",
            "value_of_trade",
            "clawback_units",
            "compensation",
        ):
            _check_scalar(float(getattr(s, k)), rec[k], (*ctx, k))
        for k in (
            "epoch",
            "migrations",
            "rounds",
            "clock_escalations",
            "rationed_rows",
            "dropped_bids",
            "seller_failures",
            "failed_pools",
            "evictions",
        ):
            _check_scalar(int(getattr(s, k)), rec[k], (*ctx, k))
        for k in ("converged", "system_ok", "degraded"):
            _check_scalar(bool(getattr(s, k)), rec[k], (*ctx, k))
    np.testing.assert_array_equal(
        eco.pool_reliability, np.asarray(golden["pool_reliability"]),
        err_msg=f"{name} pool_reliability",
    )


def test_warm_golden_differs_after_epoch0():
    """The warm fixtures must actually exercise the warm path: epoch 0
    matches cold (nothing to seed from), and at least one later epoch's
    round count or prices differ from the cold trajectory."""
    for seed in SEEDS:
        cold, warm = _load(seed, False), _load(seed, True)
        c0, w0 = cold["stats"][0], warm["stats"][0]
        assert c0["prices"] == w0["prices"], seed
        assert not w0["warm_started"] and all(
            s["warm_started"] for s in warm["stats"][1:]
        ), seed
    assert any(
        _load(s, False)["stats"][e] != _load(s, True)["stats"][e]
        for s in SEEDS
        for e in (1, 2)
    )
