"""Hypothesis property tests for the weighting curves (optional dependency).

Split out of test_reserve.py so the tier-1 suite still collects and runs
when ``hypothesis`` is not installed (see requirements-dev.txt).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import CURVE_FAMILIES  # noqa: E402


@settings(max_examples=50, deadline=None)
@given(psi=st.floats(0, 1), name=st.sampled_from(list(CURVE_FAMILIES)))
def test_property_weights_positive_finite(psi, name):
    v = float(CURVE_FAMILIES[name](np.float32(psi)))
    assert np.isfinite(v) and v > 0


@settings(max_examples=60, deadline=None)
@given(
    psi_a=st.floats(0, 1),
    psi_b=st.floats(0, 1),
    name=st.sampled_from(list(CURVE_FAMILIES)),
)
def test_property_weights_monotone_in_utilization(psi_a, psi_b, name):
    """§IV.A property 1 for every curve family: ψ₁ ≤ ψ₂ ⇒ φ(ψ₁) ≤ φ(ψ₂),
    hence reserve prices are monotone in utilization under all weightings."""
    lo, hi = sorted((psi_a, psi_b))
    phi = CURVE_FAMILIES[name]
    v_lo = float(phi(np.float32(lo)))
    v_hi = float(phi(np.float32(hi)))
    assert v_lo <= v_hi * (1 + 1e-6), (name, lo, hi, v_lo, v_hi)


@settings(max_examples=40, deadline=None)
@given(
    psi=st.lists(st.floats(0, 1), min_size=1, max_size=8),
    name=st.sampled_from(list(CURVE_FAMILIES)),
    cost=st.floats(0.01, 100.0),
)
def test_property_reserve_prices_monotone(psi, name, cost):
    """reserve_prices itself (φ·c) preserves the utilization ordering for a
    fixed base cost, under all three weightings."""
    from repro.core import ResourcePool
    from repro.core.reserve import reserve_prices

    pools = [
        ResourcePool("c", "r", base_cost=cost, utilization=p) for p in psi
    ]
    prices = reserve_prices(pools, CURVE_FAMILIES[name])
    order = np.argsort(np.asarray(psi, np.float32), kind="stable")
    sorted_prices = prices[order]
    assert (np.diff(sorted_prices) >= -1e-6 * np.abs(sorted_prices[:-1])).all()
