"""Hypothesis property tests for the weighting curves (optional dependency).

Split out of test_reserve.py so the tier-1 suite still collects and runs
when ``hypothesis`` is not installed (see requirements-dev.txt).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import CURVE_FAMILIES


@settings(max_examples=50, deadline=None)
@given(psi=st.floats(0, 1), name=st.sampled_from(list(CURVE_FAMILIES)))
def test_property_weights_positive_finite(psi, name):
    v = float(CURVE_FAMILIES[name](np.float32(psi)))
    assert np.isfinite(v) and v > 0
