"""CI smoke for the runnable examples — the quickstart and market sim must
not rot: they run in a fresh subprocess exactly as the README tells a user
to run them, and their printed outcomes are asserted (convergence,
SYSTEM-feasibility, and that the market actually moves agents)."""
import os
import re
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_example(script: str, *args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "examples", script), *args],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=900,
    )


def test_quickstart_smoke():
    out = _run_example("quickstart.py")
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "SYSTEM feasible: True" in out.stdout
    assert "settled unit prices" in out.stdout


def test_market_sim_smoke():
    out = _run_example("market_sim.py", "--epochs", "4", "--seed", "3")
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "all epochs SYSTEM-feasible: True" in out.stdout
    m = re.search(r"total migrations: (\d+)", out.stdout)
    assert m, out.stdout
    assert int(m.group(1)) > 0, "the market must move agents"


def test_market_sim_scenario_smoke():
    out = _run_example(
        "market_sim.py", "--scenario", "congestion_relief",
        "--epochs", "4", "--seed", "3",
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "all epochs converged: True" in out.stdout
    assert "all epochs SYSTEM-feasible: True" in out.stdout
    m = re.search(r"total migrations: (\d+)", out.stdout)
    assert m and int(m.group(1)) > 0, out.stdout


def test_market_service_demo_smoke():
    out = _run_example(
        "market_service_demo.py", "--agents", "300", "--ticks", "3",
        "--seed", "0",
    )
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "churn synced" in out.stdout
    assert "killed + resumed" in out.stdout
    assert "WAL records replayed" in out.stdout
    assert "incremental book bit-identical to full repack: True" in out.stdout
    assert "SYSTEM ok=True" in out.stdout


def test_market_sim_lists_scenarios():
    out = _run_example("market_sim.py", "--list-scenarios")
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    for name in (
        "congestion_relief", "cluster_drain", "price_shock", "flash_crowd", "sticky_relocation"
    ):
        assert name in out.stdout
