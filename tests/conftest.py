def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")
