"""Sparse-bundle settlement engine: sparse vs dense parity + kernel checks.

The sparse path is the primary settlement encoding, so every behavior the
dense reference defines must be reproduced: z / chosen / active agreement in
scalar-π and vector-π modes, padded XOR slots, all-invalid users, duplicate
pool indices within one bundle, and the Pallas kernel under interpret=True.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    ClockConfig,
    SparseAuctionProblem,
    clock_auction,
    densify,
    pack_bids,
    pack_bids_sparse,
    proxy_demand,
    sparse_proxy_demand,
    sparsify,
    surplus_and_trade,
    verify_system,
)
from repro.core.auction import sparse_proxy_demand_exact
from repro.kernels import ops, ref
from repro.kernels.sparse_bid_eval import sparse_bid_eval as pallas_sparse_bid_eval

RNG = np.random.default_rng(7)


def _random_problem(U, B, R, nnz=3, pad_prob=0.25, seed=None):
    """Random dense problem with ≤nnz nonzeros per bundle + padded XOR slots."""
    rng = np.random.default_rng(seed) if seed is not None else RNG
    bl, pis = [], []
    for _ in range(U):
        n_alt = int(rng.integers(1, B + 1))
        alts = []
        for _ in range(n_alt):
            q = np.zeros(R, np.float32)
            k = int(rng.integers(1, nnz + 1))
            q[rng.choice(R, size=k, replace=False)] = rng.uniform(-2, 4, size=k)
            alts.append(q)
        bl.append(alts)
        pis.append(float(rng.uniform(-5, 15)))
    prob = pack_bids(bl, pis, base_cost=np.ones(R, np.float32))
    return prob


def _prices(R, seed=0):
    return jnp.asarray(
        np.abs(np.random.default_rng(seed).normal(size=R)).astype(np.float32)
    )


# ---------------------------------------------------------------------------
# converters
# ---------------------------------------------------------------------------


def test_sparsify_densify_roundtrip():
    prob = _random_problem(23, 3, 17, seed=0)
    sp = sparsify(prob)
    back = densify(sp)
    np.testing.assert_array_equal(np.asarray(prob.bundles), np.asarray(back.bundles))
    np.testing.assert_array_equal(
        np.asarray(prob.bundle_mask), np.asarray(back.bundle_mask)
    )


def test_pack_bids_sparse_matches_sparsify():
    rng = np.random.default_rng(1)
    R = 11
    bl = [[np.zeros(R, np.float32)] for _ in range(4)]
    for row in bl:
        row[0][rng.choice(R, 2, replace=False)] = rng.uniform(1, 3, 2)
    dense = pack_bids(bl, [1.0] * 4, base_cost=np.ones(R, np.float32))
    sp_a = sparsify(dense)
    sp_b = pack_bids_sparse(bl, [1.0] * 4, base_cost=np.ones(R, np.float32))
    np.testing.assert_array_equal(np.asarray(sp_a.idx), np.asarray(sp_b.idx))
    np.testing.assert_array_equal(np.asarray(sp_a.val), np.asarray(sp_b.val))
    np.testing.assert_array_equal(
        np.asarray(sp_a.supply_scale), np.asarray(sp_b.supply_scale)
    )


def test_pack_bids_sparse_accepts_idx_val_pairs():
    R = 9
    bl = [[(np.array([7, 2]), np.array([1.5, -2.0]))]]  # unsorted on purpose
    sp = pack_bids_sparse(bl, [3.0], base_cost=np.ones(R, np.float32))
    np.testing.assert_array_equal(np.asarray(sp.idx[0, 0]), [2, 7])
    np.testing.assert_array_equal(np.asarray(sp.val[0, 0]), [-2.0, 1.5])


def test_pack_bids_sparse_rejects_out_of_range_indices():
    R = 3
    for bad in ([-1], [R]):
        with pytest.raises(ValueError):
            pack_bids_sparse(
                [[(np.array(bad), np.array([1.0]))]],
                [1.0],
                base_cost=np.ones(R, np.float32),
            )


def test_sparsify_k_max_too_small_raises():
    prob = _random_problem(5, 2, 10, nnz=4, seed=2)
    with pytest.raises(ValueError):
        sparsify(prob, k_max=1)


# ---------------------------------------------------------------------------
# demand parity: scalar-π and vector-π
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("U,B,R", [(4, 1, 3), (33, 3, 18), (120, 4, 130)])
def test_sparse_demand_matches_dense_scalar_pi(U, B, R):
    prob = _random_problem(U, B, R, seed=U)
    sp = sparsify(prob)
    prices = _prices(R, seed=U)
    x, ch_d, act_d = proxy_demand(prob.bundles, prob.bundle_mask, prob.pi, prices)
    z_s, ch_s, act_s = sparse_proxy_demand(
        sp.idx, sp.val, sp.bundle_mask, sp.pi, prices, R
    )
    np.testing.assert_array_equal(np.asarray(ch_d), np.asarray(ch_s))
    np.testing.assert_array_equal(np.asarray(act_d), np.asarray(act_s))
    np.testing.assert_allclose(
        np.asarray(x.sum(0)), np.asarray(z_s), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("U,B,R", [(4, 1, 3), (33, 3, 18), (120, 4, 130)])
def test_sparse_demand_matches_dense_vector_pi(U, B, R):
    prob = _random_problem(U, B, R, seed=U + 1)
    piv = jnp.asarray(
        np.random.default_rng(U).uniform(-5, 15, size=(U, prob.num_bundles)).astype(
            np.float32
        )
    )
    sp = sparsify(prob)
    prices = _prices(R, seed=U + 1)
    x, ch_d, act_d = proxy_demand(prob.bundles, prob.bundle_mask, piv, prices)
    z_s, ch_s, act_s = sparse_proxy_demand(
        sp.idx, sp.val, sp.bundle_mask, piv, prices, R
    )
    np.testing.assert_array_equal(np.asarray(ch_d), np.asarray(ch_s))
    np.testing.assert_array_equal(np.asarray(act_d), np.asarray(act_s))
    np.testing.assert_allclose(
        np.asarray(x.sum(0)), np.asarray(z_s), rtol=1e-5, atol=1e-5
    )


def test_sparse_demand_exact_is_bitwise():
    """The exact variant must reproduce the dense column reduction bit for bit
    (that is its contract — the Economy swap depends on it)."""
    prob = _random_problem(64, 3, 21, seed=5)
    sp = sparsify(prob)
    prices = _prices(21, seed=5)
    x, _, _ = proxy_demand(prob.bundles, prob.bundle_mask, prob.pi, prices)
    z_e, _, _ = sparse_proxy_demand_exact(
        sp.idx, sp.val, sp.bundle_mask, sp.pi, prices, 21
    )
    np.testing.assert_array_equal(np.asarray(x.sum(0)), np.asarray(z_e))


def test_all_invalid_user_drops_out():
    prob = _random_problem(8, 2, 6, seed=3)
    mask = np.asarray(prob.bundle_mask).copy()
    mask[3, :] = False
    prob = dataclasses.replace(prob, bundle_mask=jnp.asarray(mask))
    sp = sparsify(prob)
    prices = _prices(6, seed=3)
    for pi in (prob.pi, jnp.zeros((8, prob.num_bundles), jnp.float32)):
        z, ch, act = sparse_proxy_demand(
            sp.idx, sp.val, sp.bundle_mask, pi, prices, 6
        )
        assert int(ch[3]) == -1 and not bool(act[3])
        zk, chk = ops.sparse_bid_eval(
            sp.idx, sp.val, sp.bundle_mask, pi, prices, 6, backend="interpret"
        )
        assert int(chk[3]) == -1


def test_duplicate_indices_within_bundle():
    """Duplicate pool indices in one bundle sum — same as a dense bundle whose
    entry is the sum of the duplicates — in cost, z, and settlement."""
    R = 5
    idx = np.array([[[2, 2, 4]]], np.int32)
    val = np.array([[[1.0, 2.0, 0.5]]], np.float32)
    sp = SparseAuctionProblem(
        idx=jnp.asarray(idx),
        val=jnp.asarray(val),
        bundle_mask=jnp.asarray([[True]]),
        pi=jnp.asarray([100.0], jnp.float32),
        base_cost=jnp.ones((R,), jnp.float32),
        supply_scale=jnp.ones((R,), jnp.float32),
        num_resources=R,
    )
    dense = densify(sp)
    assert float(dense.bundles[0, 0, 2]) == 3.0
    prices = _prices(R, seed=9)
    x, ch_d, _ = proxy_demand(dense.bundles, dense.bundle_mask, dense.pi, prices)
    z_s, ch_s, _ = sparse_proxy_demand(
        sp.idx, sp.val, sp.bundle_mask, sp.pi, prices, R
    )
    np.testing.assert_array_equal(np.asarray(ch_d), np.asarray(ch_s))
    np.testing.assert_allclose(np.asarray(x.sum(0)), np.asarray(z_s), rtol=1e-6)
    zk, chk = ops.sparse_bid_eval(
        sp.idx, sp.val, sp.bundle_mask, sp.pi, prices, R, backend="interpret"
    )
    np.testing.assert_allclose(np.asarray(z_s), np.asarray(zk), rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ch_s), np.asarray(chk))


# ---------------------------------------------------------------------------
# Pallas kernel (interpret mode on CPU) vs jnp oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("U,B,R,K", [(4, 1, 3, 1), (33, 3, 18, 4), (130, 5, 200, 8)])
@pytest.mark.parametrize("vector_pi", [False, True])
def test_sparse_kernel_matches_oracle(U, B, R, K, vector_pi):
    rng = np.random.default_rng(U + K)
    idx = rng.integers(0, R, size=(U, B, K)).astype(np.int32)
    idx.sort(axis=-1)
    val = (rng.normal(size=(U, B, K)) * 2).astype(np.float32)
    # knock out some slots (padding) and some whole bundles (XOR padding)
    val[rng.random((U, B, K)) < 0.3] = 0.0
    mask = rng.random((U, B)) < 0.85
    mask[:, 0] = True
    if vector_pi:
        pi = (rng.normal(size=(U, B)) * 5).astype(np.float32)
    else:
        pi = (rng.normal(size=(U,)) * 5).astype(np.float32)
    prices = np.abs(rng.normal(size=R)).astype(np.float32)
    args = tuple(map(jnp.asarray, (idx, val, mask, pi, prices)))
    z0, c0 = ref.sparse_bid_eval(*args, R)
    z1, c1 = pallas_sparse_bid_eval(*args, R, interpret=True)
    np.testing.assert_allclose(np.asarray(z0), np.asarray(z1), rtol=3e-3, atol=3e-3)
    np.testing.assert_array_equal(np.asarray(c0), np.asarray(c1))


def test_ops_sparse_backend_dispatch():
    prob = _random_problem(16, 2, 9, seed=11)
    sp = sparsify(prob)
    prices = _prices(9, seed=11)
    za, ca = ops.sparse_bid_eval(
        sp.idx, sp.val, sp.bundle_mask, sp.pi, prices, 9, backend="jnp"
    )
    zb, cb = ops.sparse_bid_eval(
        sp.idx, sp.val, sp.bundle_mask, sp.pi, prices, 9, backend="interpret"
    )
    np.testing.assert_allclose(np.asarray(za), np.asarray(zb), rtol=1e-4, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(ca), np.asarray(cb))


def test_ops_dense_vector_pi_routes_through_sparse_kernel():
    """The old silent fallback is gone: vector-π with backend='interpret'
    must run the sparse kernel and still agree with the jnp proxy."""
    prob = _random_problem(12, 3, 7, seed=13)
    piv = jnp.asarray(
        np.random.default_rng(13).uniform(-5, 15, size=(12, prob.num_bundles)).astype(
            np.float32
        )
    )
    prices = _prices(7, seed=13)
    x_ref, ch_ref, act_ref = proxy_demand(
        prob.bundles, prob.bundle_mask, piv, prices
    )
    demand = ops.bid_demand_fn(backend="interpret")
    x, ch, act = demand(prob.bundles, prob.bundle_mask, piv, prices)
    np.testing.assert_array_equal(np.asarray(ch_ref), np.asarray(ch))
    np.testing.assert_array_equal(np.asarray(act_ref), np.asarray(act))
    np.testing.assert_allclose(np.asarray(x_ref), np.asarray(x), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# end-to-end: clock auction on the sparse encoding
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("vector_pi", [False, True])
def test_clock_auction_sparse_matches_dense(vector_pi):
    prob = _random_problem(40, 3, 15, seed=17)
    if vector_pi:
        piv = jnp.asarray(
            np.random.default_rng(17)
            .uniform(-5, 15, size=(40, prob.num_bundles))
            .astype(np.float32)
        )
        prob = dataclasses.replace(prob, pi=piv)
    sp = sparsify(prob)
    p0 = jnp.full((15,), 0.5)
    cfg = ClockConfig(max_rounds=3000)
    rd = clock_auction(prob, p0, cfg)
    rs = clock_auction(sp, p0, cfg)
    np.testing.assert_allclose(
        np.asarray(rd.prices), np.asarray(rs.prices), rtol=1e-5, atol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(rd.won), np.asarray(rs.won))
    np.testing.assert_array_equal(
        np.asarray(rd.chosen_bundle), np.asarray(rs.chosen_bundle)
    )
    np.testing.assert_allclose(
        np.asarray(rd.payments), np.asarray(rs.payments), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_allclose(
        np.asarray(rd.allocations),
        np.asarray(rs.allocations_dense(15)),
        rtol=1e-5,
        atol=1e-5,
    )
    assert verify_system(prob, rd) == verify_system(sp, rs)
    sd, td = surplus_and_trade(prob, rd)
    ss, ts = surplus_and_trade(sp, rs)
    np.testing.assert_allclose(float(sd), float(ss), rtol=1e-4)
    np.testing.assert_allclose(float(td), float(ts), rtol=1e-4)


def test_clock_auction_sparse_with_kernel_demand_fn():
    prob = _random_problem(24, 2, 10, seed=19)
    sp = sparsify(prob)
    p0 = jnp.full((10,), 0.5)
    cfg = ClockConfig(max_rounds=2000)
    r_jnp = clock_auction(sp, p0, cfg)
    r_krn = clock_auction(sp, p0, cfg, demand_fn=ops.sparse_bid_demand_fn("interpret"))
    np.testing.assert_allclose(
        np.asarray(r_jnp.prices), np.asarray(r_krn.prices), rtol=1e-4, atol=1e-4
    )
    np.testing.assert_array_equal(np.asarray(r_jnp.won), np.asarray(r_krn.won))


def test_clock_auction_rejects_mismatched_demand_fn():
    prob = _random_problem(4, 1, 3, seed=23)
    sp = sparsify(prob)
    p0 = jnp.full((3,), 0.5)
    with pytest.raises(TypeError):
        clock_auction(sp, p0, ClockConfig(), demand_fn=proxy_demand)
    with pytest.raises(TypeError):
        clock_auction(prob, p0, ClockConfig(), demand_fn=sparse_proxy_demand)
