"""Reproduce the paper's §V experimental economy: six periodic auctions over
a planet-wide fleet, with adaptive bidders, arbitrageurs, and relocation
costs.  Prints Table-I-style premium statistics, Fig-6-style price ratios,
and Fig-7-style utilization percentiles of settled trades.

    PYTHONPATH=src python examples/market_sim.py [--epochs 6] [--seed 3]

Or run a library scenario (outages, flash crowds, price shocks, ...):

    PYTHONPATH=src python examples/market_sim.py --scenario cluster_drain
    PYTHONPATH=src python examples/market_sim.py --list-scenarios
"""
import argparse
import sys

import numpy as np

from repro.core.economy import make_fleet_economy
from repro.core.scenarios import SCENARIOS, run_scenario


def run_scenario_mode(args) -> None:
    eco, sc = SCENARIOS[args.scenario](seed=args.seed, epochs=args.epochs)
    print(f"scenario: {sc.name} — {sc.description}")
    print(f"fleet: {len(eco.clusters)} clusters × {eco.rtypes}, {len(eco.pop)} engineering teams")
    if eco.policies:
        counts = np.bincount(eco.pop.policy, minlength=len(eco.policies))
        mix = ", ".join(
            f"{type(p).__name__}×{int(c)}" for p, c in zip(eco.policies, counts)
        )
        print(f"policy mix: {mix}")
    res = run_scenario(eco, sc, verbose=True)
    print("\n== outcome ==")
    print(f"events applied: {len(res.events)}")
    util0 = [round(float(s.psi[:eco.T].mean()), 3) for s in res.stats]
    print(f"cluster-0 utilization per epoch: {util0}")
    print(f"utilization spread trajectory: {[round(s, 3) for s in res.util_spread]}")
    print(f"spread shrank: {res.spread_shrank}")
    print(f"total migrations: {res.total_migrations}")
    print(f"total clock rounds: {res.total_rounds}")
    print(f"all epochs converged: {res.converged}")
    print(f"all epochs SYSTEM-feasible: {res.feasible}")
    degraded = [s for s in res.stats if s.degraded]
    if degraded:
        print("\n== degraded-mode telemetry ==")
        print(f"degraded epochs: {[s.epoch for s in degraded]}")
        print(f"clock escalations: {sum(s.clock_escalations for s in res.stats)}")
        print(f"dropped bids: {sum(s.dropped_bids for s in res.stats)}")
        print(
            f"seller failures: {sum(s.seller_failures for s in res.stats)}, "
            f"failed pools: {sum(s.failed_pools for s in res.stats)}"
        )
        print(
            f"evictions: {sum(s.evictions for s in res.stats)}, "
            f"rationed rows: {sum(s.rationed_rows for s in res.stats)}"
        )
        print(
            f"clawback: {sum(s.clawback_units for s in res.stats):.1f} units, "
            f"compensation paid: {sum(s.compensation for s in res.stats):.2f}"
        )
        rel = eco.pool_reliability.reshape(eco.C, eco.T).min(axis=1)
        worst = int(np.argmin(rel))
        print(
            f"pool reliability (min per cluster): "
            f"{[round(float(r), 3) for r in rel]} — worst: "
            f"{eco.clusters[worst]}"
        )
    if not res.converged:
        starved = [s.epoch for s in res.stats if not s.converged]
        print(
            f"*** WARNING: epochs {starved} hit max_rounds without "
            "clearing — prices are truncated, not settled",
            file=sys.stderr,
        )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=6)
    ap.add_argument("--seed", type=int, default=3)
    ap.add_argument(
        "--scenario",
        choices=sorted(SCENARIOS),
        default=None,
        help="run a library scenario instead of the plain §V sim",
    )
    ap.add_argument("--list-scenarios", action="store_true")
    args = ap.parse_args()

    if args.list_scenarios:
        for name in sorted(SCENARIOS):
            _, sc = SCENARIOS[name](seed=0)
            print(f"{name:20s} {sc.description}")
        return
    if args.scenario:
        run_scenario_mode(args)
        return

    eco = make_fleet_economy(seed=args.seed)
    print(f"fleet: {len(eco.clusters)} clusters × {eco.rtypes}, {len(eco.pop)} engineering teams")
    print(
        f"pre-market utilization by cluster: "
        f"{(eco.utilization().mean(axis=1) * 100).round(0).tolist()}"
    )

    print("\n== Table I: bid premium statistics ==")
    print("auction  median(γ)  mean(γ)  %settled  migrations  rounds  converged")
    stats = []
    for _ in range(args.epochs):
        s = eco.run_epoch()
        stats.append(s)
        print(
            f"  {s.epoch:2d}     {s.gamma_median:8.4f} {s.gamma_mean:8.4f}  "
            f"{s.pct_settled:6.1f}%   {s.migrations:4d}       {s.rounds:5d}  "
            f"{s.converged}"
        )
        if not s.converged:
            print(
                f"  *** WARNING: epoch {s.epoch} hit max_rounds="
                f"{eco.clock.max_rounds} without clearing — prices are "
                "truncated, not settled (raise max_rounds, enable the "
                "adaptive schedule, or warm-start the economy)",
                file=sys.stderr,
            )

    print("\n== Fig 6: settled price / former fixed price (last auction) ==")
    r = stats[-1].price_ratio.reshape(eco.C, eco.T)
    for c, name in enumerate(eco.clusters):
        print(f"  {name}: " + "  ".join(f"{eco.rtypes[t]}={r[c, t]:.2f}x" for t in range(eco.T)))

    print("\n== Fig 7: utilization percentile of settled trades ==")
    buys = np.concatenate([s.buy_util_percentiles for s in stats])
    sells = np.concatenate([s.sell_util_percentiles for s in stats])
    for name, arr in (("bids (buys)", buys), ("offers (sells)", sells)):
        if len(arr):
            q = np.percentile(arr, [25, 50, 75]).round(0)
            print(f"  {name:15s} n={len(arr):3d}  quartiles {q.tolist()}")

    print("\n== outcome ==")
    print(
        f"post-market utilization by cluster: "
        f"{(eco.utilization().mean(axis=1) * 100).round(0).tolist()}"
    )
    print(f"utilization spread (std across clusters): {np.std(eco.utilization().mean(axis=1)):.3f}")
    print(f"total migrations: {sum(s.migrations for s in stats)}")
    print(f"all epochs SYSTEM-feasible: {all(s.system_ok for s in stats)}")


if __name__ == "__main__":
    main()
