"""Quickstart: one clock auction over two clusters, end to end.

    PYTHONPATH=src python examples/quickstart.py

Shows the paper's §III/§IV machinery in ~40 lines: congestion-weighted
reserve prices, operator supply, XOR bids via the tree bidding language,
ascending-clock settlement, and the SYSTEM feasibility checks.
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    All, OneOf, Res, flatten, pool_index,
    ResourcePool, reserve_prices, operator_supply_bids, pack_bids,
    clock_auction, verify_system, surplus_and_trade,
)

# -- pools: (cluster × resource) with base costs + current utilization --------
pools = [
    ResourcePool("us-east", "tpu_chips", base_cost=10.0, utilization=0.93, supply=512),
    ResourcePool("us-east", "hbm_gb", base_cost=0.05, utilization=0.90, supply=8192),
    ResourcePool("eu-west", "tpu_chips", base_cost=10.0, utilization=0.35, supply=512),
    ResourcePool("eu-west", "hbm_gb", base_cost=0.05, utilization=0.30, supply=8192),
]
idx = pool_index([p.name for p in pools])

tilde_p = reserve_prices(pools)
print("reserve prices (congestion-weighted):")
for p, r in zip(pools, tilde_p):
    print(f"  {p.name:20s} util={p.utilization:.0%}  c(r)=${p.base_cost:<6} -> p̃=${r:.3f}")

# -- bids ----------------------------------------------------------------------
bundle_lists, pis = operator_supply_bids(pools, tilde_p, lots=4)

# Team A: 256 chips + 4 TB HBM, either cluster (XOR), pays up to $6k
tree_a = OneOf(
    All(Res("us-east/tpu_chips", 256), Res("us-east/hbm_gb", 4096)),
    All(Res("eu-west/tpu_chips", 256), Res("eu-west/hbm_gb", 4096)),
)
bundle_lists.append(flatten(tree_a, idx))
pis.append(6000.0)

# Team B: must stay in congested us-east (data gravity), pays a premium
tree_b = All(Res("us-east/tpu_chips", 128), Res("us-east/hbm_gb", 2048))
bundle_lists.append(flatten(tree_b, idx))
pis.append(9000.0)

# Team C: low-value batch job, happy anywhere
tree_c = OneOf(
    All(Res("us-east/tpu_chips", 128), Res("us-east/hbm_gb", 1024)),
    All(Res("eu-west/tpu_chips", 128), Res("eu-west/hbm_gb", 1024)),
)
bundle_lists.append(flatten(tree_c, idx))
pis.append(1500.0)

prob = pack_bids(bundle_lists, pis, base_cost=np.array([p.base_cost for p in pools]))
res = clock_auction(prob, jnp.asarray(tilde_p))

print(
    f"\nclock converged in {int(res.rounds)} rounds; SYSTEM feasible: "
    f"{all(verify_system(prob, res).values())}"
)
print("settled unit prices:")
for p, pr0, pr1 in zip(pools, tilde_p, np.asarray(res.prices)):
    print(f"  {p.name:20s} reserve ${pr0:.3f} -> settled ${pr1:.3f}")

names = ["team-A", "team-B", "team-C"]
for u, name in zip(range(prob.num_users - 3, prob.num_users), names):
    won = bool(res.won[u])
    pay = float(res.payments[u])
    alloc = np.asarray(res.allocations[u])
    where = [pools[r].name for r in np.nonzero(alloc > 0)[0]]
    print(f"  {name}: {'WON ' + str(where) + f' for ${pay:.0f}' if won else 'priced out'}")

s, t = surplus_and_trade(prob, res)
print(f"realized surplus ${float(s):.0f}, value of trade ${float(t):.0f}")
