"""Always-on market service: stream bid deltas into a persistent device
book, settle on a tick, poll prices between auctions.

The paper runs its clock auction "at regular time intervals"; this demo is
that loop in production shape — a :class:`repro.serve.market.MarketService`
bridged from a fleet economy, absorbing a stream of re-priced bids, agent
churn (arrivals and departures routed through the economy's O(Δ) dirty-uid
bridge), and withdrawals, then auctioning the book each tick with warm-
started prices.  The incremental book is checked bit-identical to a
from-scratch repack at the end (``MarketBook.parity_check``).

    PYTHONPATH=src python examples/market_service_demo.py \
        [--agents 800] [--ticks 4] [--churn 0.05] [--seed 0]
"""
import argparse
import time

import numpy as np

from repro.core.markets import fleet_economy, fleet_population
from repro.serve.market import BidDelta, MarketService


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=800)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--ticks", type=int, default=4)
    ap.add_argument("--churn", type=float, default=0.05,
                    help="fraction of agents re-pricing per tick")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    eco = fleet_economy(args.agents, args.clusters, seed=args.seed)
    svc = MarketService.from_economy(eco)
    rng = np.random.default_rng(args.seed)
    print(
        f"book: {svc.book.num_rows} rows ({svc.book.rows_cap} slots), "
        f"{eco.C} clusters x {eco.T} rtypes"
    )
    p, epoch = svc.poll_prices()
    print(f"poll before any tick -> reserve curve (epoch {epoch})")

    keys, idx_rows, val_rows, mask_rows, pi_rows = eco.export_bid_rows()
    live = np.flatnonzero(mask_rows.any(axis=1))
    for t in range(args.ticks):
        # a) streamed re-pricing: a churn-fraction of agents nudge their pi
        pick = rng.choice(live, size=max(1, int(args.churn * live.size)),
                          replace=False)
        scale = rng.uniform(0.9, 1.1, size=pick.size).astype(np.float32)
        accepted = 0
        for j, i in enumerate(pick):
            bundles = [
                (idx_rows[i, b], val_rows[i, b])
                for b in np.flatnonzero(mask_rows[i])
            ]
            accepted += svc.submit(
                BidDelta(keys[i], bundles, pi_rows[i][mask_rows[i]] * scale[j])
            )
        # b) population churn rides the economy bridge in O(Δ)
        if t == 1:
            keep = np.ones(len(eco.pop), bool)
            keep[:: max(2, len(eco.pop) // 20)] = False
            keep[0] = True
            eco.remove_agents(~keep)
            eco.add_agents(
                fleet_population(8, eco.C, seed=args.seed + t, placed_frac=0.0)
            )
            ups, wd = svc.sync_from_economy(eco)
            print(f"tick {t}: churn synced — {ups} upserts, {wd} withdrawals")
            keys, idx_rows, val_rows, mask_rows, pi_rows = eco.export_bid_rows()
            live = np.flatnonzero(mask_rows.any(axis=1))
        t0 = time.time()
        s = svc.tick()
        dt = (time.time() - t0) * 1e3
        print(
            f"tick {t}: {accepted} bids in, {s.rounds} rounds, "
            f"converged={s.converged}, SYSTEM ok={s.system_ok}, "
            f"pct_settled={s.pct_settled:.1f}%, {dt:.0f} ms"
        )
    p, epoch = svc.poll_prices()
    print(f"posted prices (epoch {epoch}): {np.round(p, 3).tolist()[:6]} ...")
    svc.book.parity_check()
    print("incremental book bit-identical to full repack: True")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
