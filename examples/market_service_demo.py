"""Always-on market service: stream bid deltas into a persistent device
book, settle on a tick, poll prices between auctions — durably.

The paper runs its clock auction "at regular time intervals"; this demo is
that loop in production shape — a :class:`repro.serve.market.MarketService`
bridged from a fleet economy, absorbing a stream of re-priced bids, agent
churn (arrivals and departures routed through the economy's O(Δ) dirty-uid
bridge), explicit withdrawals, and fault-injected bid dropout, then
auctioning the book each tick with warm-started prices.  Midway through the
horizon the service is hard-dropped — no drain, no shutdown hook — and
resumed from its write-ahead log + latest checkpoint, after which the loop
continues as if nothing happened (the recovery suite proves bit-identical;
here the book's ``parity_check`` oracle and the continuing epoch counter
show it live).  The incremental book is checked bit-identical to a
from-scratch repack at the end.

    PYTHONPATH=src python examples/market_service_demo.py \
        [--agents 800] [--ticks 4] [--churn 0.05] [--dropout 0.1] [--seed 0]
"""
import argparse
import os
import tempfile
import time

import numpy as np

from repro.core.faults import FaultModel
from repro.core.markets import fleet_economy, fleet_population
from repro.serve import ServiceConfig
from repro.serve.market import BidDelta, MarketService


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--agents", type=int, default=800)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--ticks", type=int, default=4)
    ap.add_argument("--churn", type=float, default=0.05,
                    help="fraction of agents re-pricing per tick")
    ap.add_argument("--withdraw-frac", type=float, default=0.02,
                    help="fraction of agents withdrawing per tick")
    ap.add_argument("--dropout", type=float, default=0.1,
                    help="per-tick bid-stream dropout probability")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    eco = fleet_economy(args.agents, args.clusters, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    faults = FaultModel(bid_dropout=args.dropout, seed=args.seed)
    tmp = tempfile.mkdtemp(prefix="market_demo_")
    cfg = ServiceConfig(
        wal_path=os.path.join(tmp, "market.wal"),
        checkpoint_dir=os.path.join(tmp, "ckpt"),
    )
    svc = MarketService.from_economy(eco, config=cfg, faults=faults)
    print(
        f"book: {svc.book.num_rows} rows ({svc.book.rows_cap} slots), "
        f"{eco.C} clusters x {eco.T} rtypes; durable in {tmp}"
    )
    p, epoch = svc.poll_prices()
    print(f"poll before any tick -> reserve curve (epoch {epoch})")

    keys, idx_rows, val_rows, mask_rows, pi_rows = eco.export_bid_rows()
    live = np.flatnonzero(mask_rows.any(axis=1))
    withdrawn: set = set()
    for t in range(args.ticks):
        # a) streamed re-pricing: a churn-fraction of agents nudge their pi
        pick = rng.choice(live, size=max(1, int(args.churn * live.size)),
                          replace=False)
        scale = rng.uniform(0.9, 1.1, size=pick.size).astype(np.float32)
        accepted = 0
        for j, i in enumerate(pick):
            bundles = [
                (idx_rows[i, b], val_rows[i, b])
                for b in np.flatnonzero(mask_rows[i])
            ]
            accepted += svc.submit(
                BidDelta(keys[i], bundles, pi_rows[i][mask_rows[i]] * scale[j])
            )
            withdrawn.discard(keys[i])  # a re-submission revives the bid
        # b) explicit withdrawals: some agents leave the market outright
        n_wd = int(args.withdraw_frac * live.size)
        for i in rng.choice(live, size=n_wd, replace=False):
            if keys[i] not in withdrawn and svc.withdraw(keys[i]):
                withdrawn.add(keys[i])
        # c) population churn rides the economy bridge in O(Δ)
        if t == 1:
            keep = np.ones(len(eco.pop), bool)
            keep[:: max(2, len(eco.pop) // 20)] = False
            keep[0] = True
            eco.remove_agents(~keep)
            eco.add_agents(
                fleet_population(8, eco.C, seed=args.seed + t, placed_frac=0.0)
            )
            ups, wd = svc.sync_from_economy(eco)
            print(f"tick {t}: churn synced — {ups} upserts, {wd} withdrawals")
            keys, idx_rows, val_rows, mask_rows, pi_rows = eco.export_bid_rows()
            live = np.flatnonzero(mask_rows.any(axis=1))
            withdrawn &= set(keys)
        # d) hard kill + resume mid-horizon: the pending queue survives in
        #    the WAL, committed state in the checkpoint — the loop continues
        if t == args.ticks // 2:
            pend = svc.pending
            del svc  # no drain, no checkpoint, no goodbye
            svc = MarketService.from_economy(eco, config=cfg, faults=faults)
            print(
                f"tick {t}: killed + resumed — epoch {svc.epoch}, "
                f"{svc.replayed_records} WAL records replayed, "
                f"{svc.pending}/{pend} pending bids reconstructed"
            )
        t0 = time.time()
        s = svc.tick()
        dt = (time.time() - t0) * 1e3
        print(
            f"tick {t}: {accepted} bids in, {s.bids_withdrawn} out, "
            f"{s.dropped_bids} dropped, {s.rounds} rounds, "
            f"converged={s.converged}, SYSTEM ok={s.system_ok}, "
            f"health={s.health}, pct_settled={s.pct_settled:.1f}%, "
            f"peak psi={s.psi.max():.2f}, {dt:.0f} ms"
        )
    p, epoch = svc.poll_prices()
    print(f"posted prices (epoch {epoch}): {np.round(p, 3).tolist()[:6]} ...")
    svc.book.parity_check()
    print("incremental book bit-identical to full repack: True")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
