"""End-to-end driver: market-provisioned, elastic, fault-tolerant training.

The full stack in one script:
  1. an auction epoch prices two clusters and grants chips to a training job;
  2. the job builds its mesh from the grant and trains, checkpointing;
  3. mid-run, a *second* auction epoch (congestion changed) re-provisions the
     job to a different grant — the job elastically re-shards from its
     checkpoint onto the new mesh and keeps training;
  4. a simulated node failure triggers a supervisor-style restore.

Default is a CPU-sized model for a quick demo.  ``--production`` switches to
a ~100M-parameter model × 300 steps (the assignment's end-to-end scale; run
it on real accelerators or be patient on CPU).

    PYTHONPATH=src python examples/elastic_train.py [--production]
"""
import argparse
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint.checkpoint import Checkpointer
from repro.configs import get_smoke
from repro.core import (
    ClockConfig, ResourcePool, clock_auction, operator_supply_bids,
    pack_bids, reserve_prices,
)
from repro.core.provisioner import grants_from_allocation, grant_to_mesh
from repro.data.pipeline import SyntheticLM
from repro.models import ModelConfig, get_api
from repro.models.params import count_params, init_params
from repro.sharding import use_mesh
from repro.train.optimizer import AdamW
from repro.train.train_step import init_train_state, make_train_step

MODEL_100M = ModelConfig(
    name="repro-100m", family="dense", num_layers=12, d_model=512,
    num_heads=8, num_kv_heads=8, d_ff=2048, vocab_size=49152,
    qk_norm=True, act_dtype="float32",
)


def run_auction(util_east: float, job_chips: int):
    """One provisioning epoch: returns the job's DeviceGrant."""
    pools = [
        ResourcePool("us-east", "tpu_chips", 10.0, util_east, supply=256),
        ResourcePool("eu-west", "tpu_chips", 10.0, 0.30, supply=256),
    ]
    tilde_p = reserve_prices(pools)
    bl, pis = operator_supply_bids(pools, tilde_p, lots=4)
    user_jobs = [-1] * len(bl)
    bl.append([np.array([job_chips, 0], np.float32), np.array([0, job_chips], np.float32)])
    pis.append(job_chips * 10.0 * 4)
    user_jobs.append(0)
    prob = pack_bids(bl, pis, base_cost=np.array([10.0, 10.0]))
    res = clock_auction(prob, jnp.asarray(tilde_p), ClockConfig())
    grants = grants_from_allocation(
        res, ["train-job"], [p.cluster for p in pools], [p.rtype for p in pools], user_jobs
    )
    assert grants, "training job must win at reserve prices"
    g = grants[0]
    print(f"[market] grant: {g.chips} chips in {g.cluster} @ ${g.unit_price:.2f}/chip")
    return g


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--production", action="store_true", help="~100M params × 300 steps")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    args = ap.parse_args()

    cfg = MODEL_100M if args.production else get_smoke("qwen3-1.7b")
    steps = args.steps or (300 if args.production else 40)
    batch = args.batch or (8 if args.production else 4)
    seq = args.seq or (256 if args.production else 64)
    api = get_api(cfg)
    n = count_params(api.decls(cfg))
    print(f"[job] model {cfg.name}: {n/1e6:.1f}M params, {steps} steps, batch {batch} × seq {seq}")

    ckdir = tempfile.mkdtemp(prefix="elastic_train_")
    ck = Checkpointer(ckdir)
    opt = AdamW(lr=1e-3)
    step_fn = make_train_step(cfg, opt)
    pipe = SyntheticLM(cfg, batch, seq, seed=0)

    # ---- epoch 1: us-east congested → market sends the job to eu-west ------
    grant = run_auction(util_east=0.93, job_chips=128)
    mesh = grant_to_mesh(grant)
    phase_1_end = steps // 2
    with use_mesh(mesh):
        params = init_params(jax.random.PRNGKey(0), api.decls(cfg), jnp.float32)
        state = init_train_state(cfg, opt, params)
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        t0 = time.time()
        for step in range(phase_1_end):
            p = pipe(step)
            params, state, m = jstep(params, state, {k: jnp.asarray(v) for k, v in p.items()})
            if step % 10 == 0:
                print(f"[train/{grant.cluster}] step {step} loss {float(m['loss']):.4f}")
            if step % 10 == 0:
                ck.save(step, {"params": params, "state": state})
        ck.save(phase_1_end - 1, {"params": params, "state": state}, block=True)
        print(f"[train] phase 1 done in {time.time()-t0:.1f}s")

    # ---- epoch 2: congestion flipped → re-provisioned; elastic reshard -----
    grant2 = run_auction(util_east=0.20, job_chips=64)
    mesh2 = grant_to_mesh(grant2)
    with use_mesh(mesh2):
        # simulate loss of the in-memory state (node failure) → restore
        restored, manifest = ck.restore_latest({"params": params, "state": state})
        params, state = restored["params"], restored["state"]
        start = manifest["step"] + 1
        print(
            f"[elastic] resumed step {start} on new grant "
            f"({grant2.chips} chips in {grant2.cluster})"
        )
        jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        for step in range(start, steps):
            p = pipe(step)
            params, state, m = jstep(params, state, {k: jnp.asarray(v) for k, v in p.items()})
            if step % 10 == 0 or step == steps - 1:
                print(f"[train/{grant2.cluster}] step {step} loss {float(m['loss']):.4f}")
        ck.save(steps - 1, {"params": params, "state": state}, block=True)
    print(f"[done] final loss {float(m['loss']):.4f}; checkpoints in {ckdir}")


if __name__ == "__main__":
    main()
