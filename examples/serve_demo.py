"""Batched serving demo: market-priced capacity → prefill + decode loop.

The serving fleet buys capacity on the market like any other team; the grant
sets the max concurrent batch.  Generation runs as one compiled program
(prefill warmup + greedy/temperature decode).

    PYTHONPATH=src python examples/serve_demo.py [--batch 4] [--new 24]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.models import get_api
from repro.models.params import init_params
from repro.serve.decode import generate, make_serve_steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    api = get_api(cfg)
    params = init_params(jax.random.PRNGKey(0), api.decls(cfg), jnp.float32)

    prefill, decode = make_serve_steps(cfg)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )

    # prefill logits for the whole batch of requests
    t0 = time.time()
    logits = jax.jit(prefill)(params, {"tokens": prompt})
    logits.block_until_ready()
    print(
        f"[serve] prefill {args.batch}×{args.prompt_len}: {time.time()-t0:.2f}s "
        f"logits {logits.shape}"
    )

    # full generation loop (one compiled fori_loop)
    t0 = time.time()
    out = generate(params, cfg, prompt, max_new=args.new, temperature=args.temperature)
    out.block_until_ready()
    dt = time.time() - t0
    toks = args.batch * args.new
    print(f"[serve] generated {toks} tokens in {dt:.2f}s ({toks/dt:.1f} tok/s on this host)")
    print(f"[serve] sample continuation ids: {np.asarray(out[0, args.prompt_len:])}")


if __name__ == "__main__":
    main()
